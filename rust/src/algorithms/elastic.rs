//! Elastic fault-tolerant fleets: epoch-based membership, survivor
//! re-forming, and deterministic fault injection.
//!
//! The plain drivers treat any membership change as fatal: a dead peer
//! poisons the collectives and the whole run aborts with
//! `cluster node failed`. This module upgrades the step-wise [`Session`]
//! driver to *survive* membership changes instead:
//!
//! * **Boundary snapshots** — at every outer-iteration boundary each rank
//!   snapshots its resumable state in memory: the context timeline
//!   ([`Collectives::export_state`]), the rank-local handoff bytes, and
//!   the *full* cut-axis vector (one free metrics AllGather re-assembles
//!   it in rank order — the same world-independent representation the
//!   PR-5 re-partition handoff ships). Two snapshots are kept: a fault
//!   can strike while a boundary gather is still in flight on some rank,
//!   leaving the fleet's newest snapshots one outer apart.
//! * **Typed faults** — under elastic membership the TCP transport raises
//!   [`EpochFault`]`{epoch, rank, kind}` instead of `fail()`-aborting
//!   (socket symptoms are classified and *announced* so every survivor
//!   names the same origin). Planned faults ([`FaultPlan`]) never wait
//!   for socket symptoms: the target departs cleanly and survivors raise
//!   the matching `Injected` fault immediately — bit-deterministic on
//!   both transports under the modeled clock.
//! * **Re-form & resume** — survivors re-rendezvous at rank 0 into epoch
//!   `e+1` with contiguous re-numbered ranks
//!   ([`TcpTransport::reform`](crate::net::TcpTransport::reform)), agree
//!   on the newest boundary every survivor holds (one free metrics
//!   round), re-cut the data over the new world via the *same* weighted
//!   partition policies the up-front heterogeneity knobs use, re-shard
//!   the boundary's cut-axis state through the handoff codec, and resume.
//!   The recovery rebuild is priced on top of the restored simulated
//!   clock, so recovery work lands in the modeled timeline. Joiners adopt
//!   rank 0's boundary timeline from a bootstrap blob published in one
//!   free ragged AllGather.
//!
//! Rank 0 hosts the rendezvous, is never re-numbered (survivor ranks are
//! renumbered in sorted old-rank order), and cannot be killed — its death
//! is fatal, exactly like the non-elastic contract.
//!
//! With elasticity disabled the entrypoints route through the *exact*
//! plain-session code path — zero extra rounds, zero branching — so a
//! disabled run is bit-identical to a plain [`Session`] run on both
//! transports (test-enforced, mirroring the adaptive repartitioner's
//! disabled⇒identical precedent).

use crate::algorithms::remote::exchange_and_assemble;
use crate::algorithms::session::{run_spec_full, CheckpointPlan, Session, SessionStatus};
use crate::algorithms::spec::{ElasticSpec, FaultAction, FaultPlan, RepartitionSpec, RunSpec};
use crate::algorithms::{assemble, NodeOutput, RunResult};
use crate::data::Dataset;
use crate::net::transport::tcp::{ReformInfo, TcpTransport};
use crate::net::{
    Checked, ClusterRun, Collectives, CommStats, CtxState, EpochFault, FaultKind, NodeCtx, Trace,
    Transport,
};
use crate::obs::{EventKind, Phase};
use crate::util::bytes::{put_f64, put_f64s, put_u32, put_u64, ByteReader};
use std::collections::{BTreeSet, VecDeque};
use std::io::Write;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Boundary snapshots
// ---------------------------------------------------------------------------

/// Everything one rank needs to roll back to an outer-iteration boundary
/// — and everything the *fleet* needs to re-shard that boundary over a
/// different world, because the cut-axis state is stored as the full
/// gathered vector (world-independent).
#[derive(Clone)]
struct BoundarySnap {
    outer: usize,
    /// Context timeline (clock, busy/serial seconds, stats mirror, trace
    /// segments, straggler stream).
    ctx: CtxState,
    /// Full cut-axis vector, rank-order gathered (empty for algorithms
    /// with no sharded evolving state).
    cut_axis: Vec<f64>,
    /// Rank-local handoff bytes (iterate, rng streams, records, …).
    bytes: Vec<u8>,
    /// Backend-global priced ledger at the boundary (`Some` on shm, where
    /// the blackboard is the ledger; `None` on TCP, where the per-rank
    /// mirror is).
    global: Option<CommStats>,
}

/// Take the boundary snapshot and run the join-poll metrics round. The
/// boundary protocol is identical on both transports (same free rounds at
/// the same points), so a planned-fault run is bit-deterministic across
/// them. Returns `(snapshot, a joiner is waiting)`.
fn take_boundary<C: Collectives>(
    ctx: &mut C,
    session: &Session<C>,
    join_pending: bool,
) -> (BoundarySnap, bool) {
    let h = session.snapshot_handoff();
    let cut_axis = if h.cut_axis.is_empty() {
        Vec::new()
    } else {
        ctx.metric_all_gather_concat(&h.cut_axis)
    };
    let snap = BoundarySnap {
        outer: session.outer(),
        ctx: ctx.export_state(),
        cut_axis,
        bytes: h.bytes,
        global: ctx.global_stats(),
    };
    let mut flag = vec![if join_pending { 1.0 } else { 0.0 }];
    ctx.metric_reduce_all(&mut flag);
    (snap, flag[0] > 0.0)
}

/// Keep the newest two snapshots (see the module docs for why two).
fn push_snap(snaps: &mut VecDeque<BoundarySnap>, snap: BoundarySnap) {
    if snaps.back().map(|s| s.outer) == Some(snap.outer) {
        snaps.pop_back();
    }
    snaps.push_back(snap);
    while snaps.len() > 2 {
        snaps.pop_front();
    }
}

// ---------------------------------------------------------------------------
// Planned fault execution
// ---------------------------------------------------------------------------

enum PlanOutcome {
    None,
    Fault(EpochFault),
    /// This rank is a planned kill's target: leave the fleet cleanly.
    Depart,
}

/// Fire this boundary's unfired plan events, in plan order. Every rank
/// scans the identical plan with an identical `fired` set, so all ranks
/// take the same branch without any agreement traffic. `Kill`/`Join`
/// events stop the scan (later same-boundary events fire when the
/// boundary is revisited after recovery); a rolled-back `Delay` stays
/// fired — a transient stall that the recovery undid is not replayed.
fn apply_plan_events<C: Collectives>(
    ctx: &mut C,
    plan: &FaultPlan,
    fired: &mut BTreeSet<usize>,
    outer: usize,
    epoch: u64,
) -> PlanOutcome {
    for (idx, ev) in plan.events.iter().enumerate() {
        if ev.at_outer != outer || fired.contains(&idx) {
            continue;
        }
        fired.insert(idx);
        match ev.action {
            FaultAction::Delay(secs) => {
                if ctx.rank() == ev.rank {
                    // Priced under the modeled clock: the stall is part of
                    // the simulated timeline, deterministically.
                    ctx.advance("fault-delay", secs);
                }
            }
            FaultAction::Kill => {
                if ev.rank >= ctx.world() {
                    continue; // target already left in an earlier epoch
                }
                if ctx.rank() == ev.rank {
                    return PlanOutcome::Depart;
                }
                return PlanOutcome::Fault(EpochFault {
                    epoch,
                    rank: ev.rank,
                    kind: FaultKind::Injected,
                    detail: format!("planned kill at outer {outer}"),
                });
            }
            FaultAction::Join => {
                return PlanOutcome::Fault(EpochFault {
                    epoch,
                    rank: ctx.world(),
                    kind: FaultKind::Join,
                    detail: format!("planned join at outer {outer}"),
                });
            }
        }
    }
    PlanOutcome::None
}

// ---------------------------------------------------------------------------
// Joiner bootstrap blob (rank 0's boundary snapshot, shipped as f64 words
// over the free metrics AllGather)
// ---------------------------------------------------------------------------

struct Bootstrap {
    outer: usize,
    clock: f64,
    compute: f64,
    serial: f64,
    stats: CommStats,
    cut_axis: Vec<f64>,
    bytes: Vec<u8>,
    fired: BTreeSet<usize>,
}

fn encode_bootstrap(
    agreed: i64,
    snaps: &VecDeque<BoundarySnap>,
    fired: &BTreeSet<usize>,
) -> Result<Vec<u8>, String> {
    let snap = snaps
        .iter()
        .find(|s| s.outer as i64 == agreed)
        .ok_or_else(|| format!("elastic: rank 0 has no boundary snapshot at outer {agreed}"))?;
    let mut buf = Vec::new();
    put_u64(&mut buf, snap.outer as u64);
    put_f64(&mut buf, snap.ctx.clock);
    put_f64(&mut buf, snap.ctx.compute_seconds);
    put_f64(&mut buf, snap.ctx.serial_seconds);
    snap.ctx.stats.encode(&mut buf);
    put_u32(&mut buf, snap.cut_axis.len() as u32);
    put_f64s(&mut buf, &snap.cut_axis);
    put_u32(&mut buf, snap.bytes.len() as u32);
    buf.extend_from_slice(&snap.bytes);
    // BTreeSet iterates in ascending order — the wire order is canonical.
    put_u32(&mut buf, fired.len() as u32);
    for &i in fired {
        put_u64(&mut buf, i as u64);
    }
    Ok(buf)
}

fn decode_bootstrap(bytes: &[u8]) -> Result<Bootstrap, String> {
    let mut r = ByteReader::new(bytes);
    let outer = r.u64()? as usize;
    let clock = r.f64()?;
    let compute = r.f64()?;
    let serial = r.f64()?;
    let stats = CommStats::decode(&mut r)?;
    let ncut = r.u32()? as usize;
    let cut_axis = r.f64s(ncut)?;
    let nbytes = r.u32()? as usize;
    let payload = r.take(nbytes)?.to_vec();
    let nfired = r.u32()? as usize;
    let mut fired = BTreeSet::new();
    for _ in 0..nfired {
        fired.insert(r.u64()? as usize);
    }
    r.finish()?;
    Ok(Bootstrap {
        outer,
        clock,
        compute,
        serial,
        stats,
        cut_axis,
        bytes: payload,
        fired,
    })
}

/// Pack bytes into f64 words (length header + bit-preserving chunks) so a
/// blob can ride the metrics AllGather. Reductions never touch AllGather
/// payloads, so arbitrary bit patterns survive both transports intact.
fn bytes_to_words(bytes: &[u8]) -> Vec<f64> {
    let mut words = Vec::with_capacity(1 + bytes.len() / 8 + 1);
    words.push(f64::from_bits(bytes.len() as u64));
    for chunk in bytes.chunks(8) {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        words.push(f64::from_bits(u64::from_le_bytes(b)));
    }
    words
}

fn words_to_bytes(words: &[f64]) -> Result<Vec<u8>, String> {
    let n = words
        .first()
        .map(|w| w.to_bits() as usize)
        .ok_or("elastic: empty bootstrap blob")?;
    if words.len() < 1 + n.div_ceil(8) {
        return Err(format!(
            "elastic: bootstrap blob truncated ({} bytes claimed, {} words present)",
            n,
            words.len() - 1
        ));
    }
    let mut bytes = Vec::with_capacity((words.len() - 1) * 8);
    for w in &words[1..] {
        bytes.extend_from_slice(&w.to_bits().to_le_bytes());
    }
    bytes.truncate(n);
    Ok(bytes)
}

// ---------------------------------------------------------------------------
// TCP elastic driver
// ---------------------------------------------------------------------------

enum EpochEnd {
    Done,
    Departed,
    Fault(EpochFault),
}

fn build_tcp_ctx(transport: TcpTransport, spec: &RunSpec) -> NodeCtx<Checked<TcpTransport>> {
    let mut ctx = NodeCtx::new(Checked::from_env(transport))
        .with_compute(spec.sim.compute)
        .with_trace(spec.sim.trace)
        .with_obs(spec.sim.events);
    if let Some(&speed) = spec.sim.speeds.get(ctx.rank) {
        ctx = ctx.with_speed(speed);
    }
    if let Some(s) = spec.sim.straggler {
        ctx = ctx.with_straggler(s);
    }
    ctx
}

/// Run one rank's share of an **elastic** multi-process job. Requires a
/// transport established with elastic membership
/// ([`TcpTransport::establish_elastic`]). Returns `Some(RunResult)` on
/// rank 0, `None` elsewhere — and `None` on a rank a planned kill removed.
pub fn run_elastic_over_tcp(
    ds: &Dataset,
    spec: &RunSpec,
    transport: TcpTransport,
    es: &ElasticSpec,
) -> Option<RunResult> {
    assert_eq!(
        transport.world(),
        spec.sim.m,
        "transport world size must equal spec.sim.m"
    );
    if let Err(e) = spec.validate() {
        panic!("invalid run spec: {e}");
    }
    let wall = Instant::now(); // lint: allow(wall-clock) — diagnostic wall_seconds only
    let mut ctx = build_tcp_ctx(transport, spec);
    let spec_now = spec.clone();
    let session = Session::new(&mut ctx, ds, &spec_now);
    elastic_tcp_loop(
        ctx,
        session,
        spec_now,
        ds,
        spec,
        es,
        BTreeSet::new(),
        VecDeque::new(),
        wall,
    )
}

/// Entry point for a fresh worker joining a *running* elastic fleet:
/// dial the rendezvous ([`TcpTransport::join`]), then bootstrap from the
/// survivors' agreed boundary and run the same elastic loop.
pub fn run_elastic_joiner(
    ds: &Dataset,
    spec: &RunSpec,
    transport: TcpTransport,
    info: ReformInfo,
    es: &ElasticSpec,
) -> Option<RunResult> {
    if let Err(e) = spec.validate() {
        panic!("invalid run spec: {e}");
    }
    let wall = Instant::now(); // lint: allow(wall-clock) — diagnostic wall_seconds only
    let mut ctx = build_tcp_ctx(transport, spec);
    let mut snaps = VecDeque::new();
    let (spec_now, session, fired) =
        match bootstrap(&mut ctx, &info, None, ds, spec, &mut snaps, BTreeSet::new()) {
            Ok(v) => v,
            Err(e) => panic!("cluster node failed: rank {}: {e}", ctx.rank),
        };
    // lint: allow(raw-print) — operator-facing chaos/progress line
    println!(
        "elastic: epoch {}: joined as rank {} of {}",
        info.epoch, info.rank, info.world
    );
    elastic_tcp_loop(ctx, session, spec_now, ds, spec, es, fired, snaps, wall)
}

#[allow(clippy::too_many_arguments)]
fn elastic_tcp_loop(
    mut ctx: NodeCtx<Checked<TcpTransport>>,
    mut session: Session<NodeCtx<Checked<TcpTransport>>>,
    mut spec_now: RunSpec,
    ds: &Dataset,
    base: &RunSpec,
    es: &ElasticSpec,
    mut fired: BTreeSet<usize>,
    mut snaps: VecDeque<BoundarySnap>,
    wall: Instant,
) -> Option<RunResult> {
    let mut pending: Option<EpochFault> = None;
    let mut recoveries = 0usize;
    loop {
        // One catch-all unwind boundary per epoch: a typed EpochFault can
        // surface from the step loop *or* from the recovery rounds
        // themselves (cascading failures) — both re-enter recovery.
        let end = catch_unwind(AssertUnwindSafe(|| -> Result<EpochEnd, String> {
            if let Some(fault) = pending.take() {
                let old_rank = ctx.rank;
                if ctx.obs_enabled() {
                    // Incident stamped with the *old* epoch coordinates;
                    // the flight-recorder tail names the collectives that
                    // completed right before the fault.
                    let detail = format!("{fault}{}", ctx.flight_tail());
                    ctx.obs_emit(EventKind::Incident {
                        kind: "epoch_fault".into(),
                        detail,
                    });
                }
                let info = ctx
                    .transport_mut()
                    .inner_mut()
                    .reform(&fault)
                    .map_err(|e| format!("elastic: reform after [{fault}] failed: {e}"))?;
                if info.world < es.min_world {
                    return Err(format!(
                        "elastic: re-formed world {} is below --elastic-min-world {}",
                        info.world, es.min_world
                    ));
                }
                let taken = std::mem::take(&mut fired);
                let (sp, se, fi) =
                    bootstrap(&mut ctx, &info, Some(old_rank), ds, base, &mut snaps, taken)?;
                spec_now = sp;
                session = se;
                fired = fi;
                let _ = &spec_now; // re-cut spec lives as long as the session
                if ctx.rank == 0 {
                    // lint: allow(raw-print) — operator-facing chaos/progress line
                    println!(
                        "elastic: epoch {}: re-formed world {} (joined {}) after [{}]{}",
                        info.epoch,
                        info.world,
                        info.joined,
                        fault,
                        ctx.flight_tail()
                    );
                    let _ = std::io::stdout().flush();
                }
            }
            Ok(run_epoch(&mut ctx, &mut session, &mut snaps, &mut fired, es))
        }));
        let fault = match end {
            Ok(Ok(EpochEnd::Done)) => break,
            Ok(Ok(EpochEnd::Departed)) => {
                // lint: allow(raw-print) — operator-facing chaos/progress line
                println!("elastic: rank {} departed (planned kill)", ctx.rank);
                return None;
            }
            Ok(Ok(EpochEnd::Fault(f))) => f,
            Ok(Err(e)) => panic!("cluster node failed: rank {}: {e}", ctx.rank),
            Err(payload) => match payload.downcast::<EpochFault>() {
                Ok(f) => *f,
                Err(p) => resume_unwind(p),
            },
        };
        recoveries += 1;
        if recoveries > es.max_recoveries {
            panic!(
                "cluster node failed: rank {}: elastic: giving up after {} recoveries (last fault: {}){}",
                ctx.rank,
                es.max_recoveries,
                fault,
                ctx.flight_tail()
            );
        }
        pending = Some(fault);
    }
    let out = session.finish();
    let wall_seconds = wall.elapsed().as_secs_f64();
    exchange_and_assemble(&mut ctx, base.kind(), out, wall_seconds)
}

/// Drive boundaries until the stop policy fires or a fault interrupts the
/// epoch. Unplanned faults (a SIGKILLed peer, a socket deadline) surface
/// as [`EpochFault`] panics out of the collectives; planned ones return.
fn run_epoch(
    ctx: &mut NodeCtx<Checked<TcpTransport>>,
    session: &mut Session<NodeCtx<Checked<TcpTransport>>>,
    snaps: &mut VecDeque<BoundarySnap>,
    fired: &mut BTreeSet<usize>,
    es: &ElasticSpec,
) -> EpochEnd {
    loop {
        let join_pending = ctx.rank == 0 && ctx.transport_mut().inner_mut().pending_joiner();
        let (snap, join) = take_boundary(ctx, session, join_pending);
        push_snap(snaps, snap);
        let epoch = ctx.transport_mut().inner_mut().epoch();
        if join {
            return EpochEnd::Fault(EpochFault {
                epoch,
                rank: ctx.m,
                kind: FaultKind::Join,
                detail: "worker asked to join".into(),
            });
        }
        match apply_plan_events(ctx, &es.plan, fired, session.outer(), epoch) {
            PlanOutcome::Depart => {
                ctx.transport_mut().inner_mut().depart();
                return EpochEnd::Departed;
            }
            PlanOutcome::Fault(f) => return EpochEnd::Fault(f),
            PlanOutcome::None => {}
        }
        if es.pace_ms > 0 {
            // Wall-clock only — gives external chaos (SIGKILL, joiners) a
            // window to land mid-run; the simulated clock never sees it.
            std::thread::sleep(Duration::from_millis(es.pace_ms));
        }
        match session.step(ctx) {
            SessionStatus::Running(_) => {}
            SessionStatus::Stopped(..) => return EpochEnd::Done,
        }
    }
}

/// Post-reform recovery sync, SPMD over the new epoch's mesh. Two free
/// metrics rounds: (1) gather `(old rank, newest snapshot, second-newest)`
/// per rank and agree on the rollback boundary — the minimum newest outer
/// over survivors, which the two-deep window guarantees every survivor
/// holds; (2) when joiners were admitted, rank 0 publishes its
/// agreed-boundary snapshot as a bootstrap blob (everyone else contributes
/// an empty part to the ragged gather). Then each rank rebuilds: restore
/// the boundary timeline, let `Session` setup price the re-cut rebuild on
/// top of it, re-shard the boundary's cut-axis state, reposition the
/// outer counter. `old_rank = None` marks a joiner.
fn bootstrap(
    ctx: &mut NodeCtx<Checked<TcpTransport>>,
    info: &ReformInfo,
    old_rank: Option<usize>,
    ds: &Dataset,
    base: &RunSpec,
    snaps: &mut VecDeque<BoundarySnap>,
    fired: BTreeSet<usize>,
) -> Result<(RunSpec, Session<NodeCtx<Checked<TcpTransport>>>, BTreeSet<usize>), String> {
    // The transport already renumbered us; mirror it into the context
    // (and into the event recorder's coordinate stamps).
    ctx.rank = info.rank;
    ctx.m = info.world;
    ctx.trace = Trace::new(info.world);
    ctx.obs.set_rank(info.rank);
    ctx.obs.set_epoch(info.epoch as u32);

    let latest = snaps.back().map(|s| s.outer as f64).unwrap_or(-1.0);
    let prev = if snaps.len() >= 2 {
        snaps[snaps.len() - 2].outer as f64
    } else {
        -1.0
    };
    let mine = [old_rank.map(|r| r as f64).unwrap_or(-1.0), latest, prev];
    let table = ctx.metric_all_gather_concat(&mine);
    if table.len() != 3 * info.world {
        return Err(format!(
            "elastic: recovery sync expected {} slots, got {}",
            3 * info.world,
            table.len()
        ));
    }

    // Rollback boundary: min(newest) over survivors. A survivor with no
    // snapshot at all (a fault before the first boundary) forces a fresh
    // restart over the new world (agreed = -1).
    let mut agreed = i64::MAX;
    // lint: allow(uncosted-compute) — O(world) membership vote over a metric gather, not numeric work
    for i in 0..info.world {
        if table[3 * i] >= 0.0 {
            agreed = agreed.min(table[3 * i + 1] as i64);
        }
    }
    if agreed == i64::MAX {
        agreed = -1;
    }

    // Re-cut over the new world: survivors keep their configured speeds
    // (mapped through the old→new renumbering), joiners start at 1.0.
    // `Session` setup then re-cuts with the same weighted policies the
    // up-front heterogeneity knobs use.
    let mut spec_now = base.clone();
    spec_now.sim.m = info.world;
    spec_now.sim.speeds = if base.sim.speeds.is_empty() {
        Vec::new()
    } else {
        (0..info.world)
            .map(|i| {
                let old = table[3 * i];
                if old >= 0.0 {
                    base.sim.speeds.get(old as usize).copied().unwrap_or(1.0)
                } else {
                    1.0
                }
            })
            .collect()
    };

    let blob_words = if info.joined > 0 {
        let mine = if ctx.rank == 0 && agreed >= 0 {
            bytes_to_words(&encode_bootstrap(agreed, snaps, &fired)?)
        } else {
            Vec::new()
        };
        ctx.metric_all_gather_concat(&mine)
    } else {
        Vec::new()
    };

    let mut fired = fired;
    let session = if agreed < 0 {
        // Fresh restart over the new world: zeroed timeline, fresh state.
        let straggler = ctx.export_state().straggler;
        ctx.import_state(CtxState {
            clock: 0.0,
            compute_seconds: 0.0,
            serial_seconds: 0.0,
            stats: CommStats::default(),
            segments: Vec::new(),
            straggler,
        })?;
        if ctx.obs_enabled() {
            ctx.obs_emit(EventKind::SpanBegin {
                phase: Phase::EpochReform,
                label: format!("epoch {}", info.epoch),
            });
        }
        Session::with_cuts(ctx, ds, &spec_now, None)
    } else if old_rank.is_some() {
        let snap = snaps
            .iter()
            .find(|s| s.outer as i64 == agreed)
            .ok_or_else(|| format!("elastic: no boundary snapshot at outer {agreed}"))?
            .clone();
        ctx.import_state(snap.ctx)?;
        if ctx.obs_enabled() {
            ctx.obs_emit(EventKind::SpanBegin {
                phase: Phase::EpochReform,
                label: format!("epoch {}", info.epoch),
            });
        }
        let mut session = Session::with_cuts(ctx, ds, &spec_now, None);
        session.import_handoff(&snap.cut_axis, &snap.bytes)?;
        session.resume_at(agreed as usize);
        session
    } else {
        // Joiner: adopt rank 0's boundary timeline (identical on every
        // rank by construction) with a fresh trace and this rank's own
        // straggler stream.
        let boot = decode_bootstrap(&words_to_bytes(&blob_words)?)?;
        if boot.outer as i64 != agreed {
            return Err(format!(
                "elastic: bootstrap blob is for outer {}, agreed boundary is {agreed}",
                boot.outer
            ));
        }
        let straggler = ctx.export_state().straggler;
        ctx.import_state(CtxState {
            clock: boot.clock,
            compute_seconds: boot.compute,
            serial_seconds: boot.serial,
            stats: boot.stats,
            segments: Vec::new(),
            straggler,
        })?;
        if ctx.obs_enabled() {
            ctx.obs_emit(EventKind::SpanBegin {
                phase: Phase::EpochReform,
                label: format!("epoch {}", info.epoch),
            });
        }
        let mut session = Session::with_cuts(ctx, ds, &spec_now, None);
        session.import_handoff(&boot.cut_axis, &boot.bytes)?;
        session.resume_at(boot.outer);
        fired = boot.fired;
        session
    };
    // The span brackets exactly the priced recovery rebuild: begin at the
    // restored boundary clock, end after `Session` setup priced the
    // re-cut on top of it.
    if ctx.obs_enabled() {
        ctx.obs_emit(EventKind::SpanEnd {
            phase: Phase::EpochReform,
            label: format!("epoch {}", info.epoch),
        });
    }
    // Old-world snapshots are dead after a re-cut; the next boundary
    // starts a fresh window.
    snaps.clear();
    Ok((spec_now, session, fired))
}

// ---------------------------------------------------------------------------
// shm elastic driver (plan-driven)
// ---------------------------------------------------------------------------

/// One rank's verdict on an epoch of the shm elastic driver.
enum ShmOutcome {
    Done(NodeOutput),
    Fault {
        snap: BoundarySnap,
        fault: EpochFault,
        fired: BTreeSet<usize>,
    },
    Departed,
}

/// How a rank of the *next* epoch restores: survivors from their own
/// boundary snapshot, a joiner from rank 0's (timeline adopted, state
/// re-sharded, own straggler stream).
#[derive(Clone)]
enum RestoreSlot {
    Survivor(BoundarySnap),
    Joiner(BoundarySnap),
}

fn shm_epoch<C: Collectives>(
    ctx: &mut C,
    ds: &Dataset,
    spec_e: &RunSpec,
    es: &ElasticSpec,
    epoch: u64,
    slot: Option<&RestoreSlot>,
    mut fired: BTreeSet<usize>,
) -> ShmOutcome {
    match shm_epoch_inner(ctx, ds, spec_e, es, epoch, slot, &mut fired) {
        Ok(out) => out,
        Err(e) => panic!("{e}"),
    }
}

fn shm_epoch_inner<C: Collectives>(
    ctx: &mut C,
    ds: &Dataset,
    spec_e: &RunSpec,
    es: &ElasticSpec,
    epoch: u64,
    slot: Option<&RestoreSlot>,
    fired: &mut BTreeSet<usize>,
) -> Result<ShmOutcome, String> {
    ctx.obs_set_epoch(epoch as u32);
    let mut session = match slot {
        None => Session::new(ctx, ds, spec_e),
        Some(RestoreSlot::Survivor(snap)) => {
            ctx.import_state(snap.ctx.clone())?;
            if ctx.obs_enabled() {
                ctx.obs_emit(EventKind::SpanBegin {
                    phase: Phase::EpochReform,
                    label: format!("epoch {epoch}"),
                });
            }
            let mut s = Session::with_cuts(ctx, ds, spec_e, None);
            s.import_handoff(&snap.cut_axis, &snap.bytes)?;
            s.resume_at(snap.outer);
            s
        }
        Some(RestoreSlot::Joiner(snap)) => {
            let straggler = ctx.export_state().straggler;
            ctx.import_state(CtxState {
                clock: snap.ctx.clock,
                compute_seconds: snap.ctx.compute_seconds,
                serial_seconds: snap.ctx.serial_seconds,
                stats: snap.ctx.stats.clone(),
                segments: Vec::new(),
                straggler,
            })?;
            if ctx.obs_enabled() {
                ctx.obs_emit(EventKind::SpanBegin {
                    phase: Phase::EpochReform,
                    label: format!("epoch {epoch}"),
                });
            }
            let mut s = Session::with_cuts(ctx, ds, spec_e, None);
            s.import_handoff(&snap.cut_axis, &snap.bytes)?;
            s.resume_at(snap.outer);
            s
        }
    };
    if slot.is_some() && ctx.obs_enabled() {
        // Brackets the priced recovery rebuild, boundary clock → post-re-cut.
        ctx.obs_emit(EventKind::SpanEnd {
            phase: Phase::EpochReform,
            label: format!("epoch {epoch}"),
        });
    }
    loop {
        let (snap, _join) = take_boundary(ctx, &session, false);
        match apply_plan_events(ctx, &es.plan, fired, session.outer(), epoch) {
            PlanOutcome::Depart => return Ok(ShmOutcome::Departed),
            PlanOutcome::Fault(mut fault) => {
                // The flight-recorder tail rides in the fault detail, so
                // the driver's re-formed line (and a giving-up panic)
                // names the last completed collectives.
                fault.detail.push_str(&ctx.flight_tail());
                if ctx.obs_enabled() {
                    let detail = fault.to_string();
                    ctx.obs_emit(EventKind::Incident {
                        kind: "epoch_fault".into(),
                        detail,
                    });
                }
                return Ok(ShmOutcome::Fault {
                    snap,
                    fault,
                    fired: fired.clone(),
                })
            }
            PlanOutcome::None => {}
        }
        if es.pace_ms > 0 {
            std::thread::sleep(Duration::from_millis(es.pace_ms));
        }
        match session.step(ctx) {
            SessionStatus::Running(_) => {}
            SessionStatus::Stopped(..) => return Ok(ShmOutcome::Done(session.finish())),
        }
    }
}

/// Plan-driven elastic run on the thread cluster: one [`Cluster::run`]
/// per epoch; between epochs the driver re-maps survivor snapshots by
/// sorted old rank (exactly the TCP renumbering rule), synthesizes a
/// joiner's restore slot from rank 0's snapshot, seeds the next epoch's
/// priced ledger from the boundary's global stats, and re-launches at the
/// new world. Returns the assembled result plus the number of recoveries.
///
/// [`Cluster::run`]: crate::net::Cluster::run
pub fn run_spec_elastic(ds: &Dataset, spec: &RunSpec, es: &ElasticSpec) -> (RunResult, usize) {
    if let Err(e) = spec.validate() {
        panic!("invalid run spec: {e}");
    }
    let wall = Instant::now(); // lint: allow(wall-clock) — diagnostic wall_seconds only
    let mut world = spec.sim.m;
    let mut speeds = spec.sim.speeds.clone();
    let mut restore: Option<Vec<RestoreSlot>> = None;
    let mut fired: BTreeSet<usize> = BTreeSet::new();
    let mut global_seed: Option<CommStats> = None;
    let mut recoveries = 0usize;
    let mut epoch: u64 = 1;
    // Event streams accumulate across epochs (each epoch is its own
    // Cluster::run); the epoch stamp keeps them apart in the output.
    let mut all_events = Vec::new();
    loop {
        let mut spec_e = spec.clone();
        spec_e.sim.m = world;
        spec_e.sim.speeds = speeds.clone();
        let mut cluster = spec_e.sim.cluster();
        if let Some(stats) = global_seed.clone() {
            cluster = cluster.with_initial_stats(stats);
        }
        let fired_in = fired.clone();
        let restore_in = restore.take();
        let spec_ref = &spec_e;
        let run = cluster.run(|ctx| {
            let slot = restore_in.as_ref().map(|v| &v[ctx.rank()]);
            shm_epoch(ctx, ds, spec_ref, es, epoch, slot, fired_in.clone())
        });

        all_events.extend(run.events);
        let mut outs: Vec<NodeOutput> = Vec::new();
        let mut fault: Option<EpochFault> = None;
        let mut snaps: Vec<Option<BoundarySnap>> = (0..world).map(|_| None).collect();
        for (r, o) in run.outputs.into_iter().enumerate() {
            match o {
                ShmOutcome::Done(out) => outs.push(out),
                ShmOutcome::Fault {
                    snap,
                    fault: f,
                    fired: fi,
                } => {
                    snaps[r] = Some(snap);
                    fired = fi; // identical on every survivor
                    fault = Some(f);
                }
                ShmOutcome::Departed => {}
            }
        }
        let Some(f) = fault else {
            if outs.len() != world {
                panic!("cluster node failed: elastic: epoch outcomes diverged");
            }
            let crun = ClusterRun {
                outputs: outs,
                stats: run.stats,
                trace: run.trace,
                sim_seconds: run.sim_seconds,
                wall_seconds: wall.elapsed().as_secs_f64(),
                events: all_events,
            };
            return (assemble(spec.kind(), crun), recoveries);
        };

        recoveries += 1;
        if recoveries > es.max_recoveries {
            panic!(
                "cluster node failed: elastic: giving up after {} recoveries (last fault: {f})",
                es.max_recoveries
            );
        }
        let root_snap = snaps[0]
            .clone()
            .unwrap_or_else(|| panic!("cluster node failed: elastic: rank 0 left the fleet"));
        global_seed = root_snap.global.clone();
        match f.kind {
            FaultKind::Join => {
                let mut slots = Vec::with_capacity(world + 1);
                for snap in snaps.iter_mut() {
                    match snap.take() {
                        Some(s) => slots.push(RestoreSlot::Survivor(s)),
                        None => panic!(
                            "cluster node failed: elastic: a survivor has no boundary snapshot"
                        ),
                    }
                }
                slots.push(RestoreSlot::Joiner(root_snap));
                restore = Some(slots);
                if !speeds.is_empty() {
                    speeds.push(1.0);
                }
                world += 1;
            }
            _ => {
                let dead = f.rank;
                if world - 1 < es.min_world {
                    panic!(
                        "cluster node failed: elastic: re-formed world {} is below min world {}",
                        world - 1,
                        es.min_world
                    );
                }
                let mut slots = Vec::with_capacity(world - 1);
                for (r, snap) in snaps.iter_mut().enumerate() {
                    if r == dead {
                        continue;
                    }
                    match snap.take() {
                        Some(s) => slots.push(RestoreSlot::Survivor(s)),
                        None => panic!(
                            "cluster node failed: elastic: survivor rank {r} has no boundary snapshot"
                        ),
                    }
                }
                restore = Some(slots);
                if !speeds.is_empty() {
                    speeds.remove(dead);
                }
                world -= 1;
            }
        }
        epoch = f.epoch + 1;
        // lint: allow(raw-print) — operator-facing chaos/progress line
        println!("elastic: epoch {epoch}: re-formed world {world} after [{f}]");
    }
}

/// Route a (possibly elastic) shm run: with elasticity disabled this *is*
/// `run_spec_full` with no plan and no repartitioner — the exact plain
/// code path, zero extra rounds — so disabled ⇒ bit-identical is
/// structural, not incidental.
pub fn run_spec_maybe_elastic(
    ds: &Dataset,
    spec: &RunSpec,
    es: &ElasticSpec,
) -> (RunResult, usize) {
    if es.enabled() {
        run_spec_elastic(ds, spec, es)
    } else {
        let (result, _recuts) =
            run_spec_full(ds, spec, &CheckpointPlan::none(), &RepartitionSpec::none());
        (result, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::session::run_spec;
    use crate::algorithms::{AlgoKind, RunSpec};
    use crate::data::SyntheticConfig;
    use crate::loss::LossKind;
    use crate::net::ComputeModel;

    fn ds() -> Dataset {
        SyntheticConfig::new("elastic-test", 90, 24)
            .density(0.4)
            .seed(7)
            .generate()
    }

    fn spec(kind: AlgoKind, m: usize) -> RunSpec {
        let mut spec = RunSpec::new(kind, LossKind::Logistic, 1e-3).with_m(m);
        spec.sim.compute = ComputeModel::modeled();
        spec.stop.grad_tol = 1e-6;
        spec.stop.max_outer = 60;
        spec
    }

    #[test]
    fn words_round_trip_arbitrary_bytes() {
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65] {
            let bytes: Vec<u8> = (0..len).map(|i| (i * 37 + 11) as u8).collect();
            let words = bytes_to_words(&bytes);
            assert_eq!(words_to_bytes(&words).unwrap(), bytes);
        }
        assert!(words_to_bytes(&[]).is_err());
    }

    #[test]
    fn bootstrap_blob_round_trips() {
        let mut stats = CommStats::default();
        stats.wire_bytes = 99;
        let snap = BoundarySnap {
            outer: 5,
            ctx: CtxState {
                clock: 1.25,
                compute_seconds: 0.75,
                serial_seconds: 0.125,
                stats: stats.clone(),
                segments: Vec::new(),
                straggler: None,
            },
            cut_axis: vec![1.5, -2.25, 0.0],
            bytes: vec![1, 2, 3, 4, 5],
            global: None,
        };
        let mut snaps = VecDeque::new();
        snaps.push_back(snap);
        let fired: BTreeSet<usize> = [3usize, 1].into_iter().collect();
        let blob = encode_bootstrap(5, &snaps, &fired).unwrap();
        let boot = decode_bootstrap(&blob).unwrap();
        assert_eq!(boot.outer, 5);
        assert_eq!(boot.clock.to_bits(), 1.25f64.to_bits());
        assert_eq!(boot.stats, stats);
        assert_eq!(boot.cut_axis, vec![1.5, -2.25, 0.0]);
        assert_eq!(boot.bytes, vec![1, 2, 3, 4, 5]);
        assert_eq!(boot.fired, fired);
        // And the blob survives the f64-word packing it rides on.
        let rt = words_to_bytes(&bytes_to_words(&blob)).unwrap();
        assert_eq!(rt, blob);
    }

    #[test]
    fn planned_kill_reforms_and_converges_disco_f() {
        let ds = ds();
        let spec3 = spec(AlgoKind::DiscoF, 3);
        let baseline = run_spec(&ds, &spec3);
        assert!(baseline.converged, "baseline must converge");

        let mut es = ElasticSpec::on();
        es.plan = FaultPlan::parse("kill@2:2").unwrap();
        let (result, recoveries) = run_spec_elastic(&ds, &spec3, &es);
        assert_eq!(recoveries, 1);
        assert_eq!(result.node_ops.len(), 2, "survivors re-formed at world-1");
        assert!(result.converged, "survivors must still converge");
        assert!(
            result.final_grad_norm() <= spec3.stop.grad_tol,
            "converged to the same tolerance"
        );
        let df = (result.final_fval() - baseline.final_fval()).abs();
        assert!(df < 1e-6, "same objective to tolerance (Δf = {df:.3e})");
        assert_eq!(result.w.len(), ds.dim(), "iterate re-assembled over new cuts");
    }

    #[test]
    fn planned_kill_reforms_and_converges_sample_partitioned() {
        let ds = ds();
        for kind in [AlgoKind::Dane, AlgoKind::CocoaPlus, AlgoKind::Gd] {
            let spec3 = spec(kind, 3);
            let baseline = run_spec(&ds, &spec3);
            let mut es = ElasticSpec::on();
            es.plan = FaultPlan::parse("kill@1:1").unwrap();
            let (result, recoveries) = run_spec_elastic(&ds, &spec3, &es);
            assert_eq!(recoveries, 1, "{kind:?}");
            assert_eq!(result.node_ops.len(), 2, "{kind:?}");
            assert_eq!(result.converged, baseline.converged, "{kind:?}");
            if baseline.converged {
                let df = (result.final_fval() - baseline.final_fval()).abs();
                assert!(df < 1e-5, "{kind:?}: Δf = {df:.3e}");
            }
        }
    }

    #[test]
    fn planned_join_grows_the_world() {
        let ds = ds();
        let spec2 = spec(AlgoKind::DiscoF, 2);
        let mut es = ElasticSpec::on();
        es.plan = FaultPlan::parse("join@2").unwrap();
        let (result, recoveries) = run_spec_elastic(&ds, &spec2, &es);
        assert_eq!(recoveries, 1);
        assert_eq!(result.node_ops.len(), 3, "world grew to 3");
        assert!(result.converged);
        assert_eq!(result.w.len(), ds.dim());
    }

    #[test]
    fn delay_fault_is_priced_and_deterministic() {
        let ds = ds();
        let spec3 = spec(AlgoKind::Gd, 3);
        let mut es = ElasticSpec::on();
        es.plan = FaultPlan::parse("delay@1:1:0.5").unwrap();
        let (a, _) = run_spec_elastic(&ds, &spec3, &es);
        let (b, _) = run_spec_elastic(&ds, &spec3, &es);
        assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits());
        for (x, y) in a.w.iter().zip(b.w.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // The stall lands in the modeled timeline.
        let (plain, _) = run_spec_elastic(&ds, &spec3, &ElasticSpec::on());
        assert!(a.sim_seconds > plain.sim_seconds + 0.49);
    }

    #[test]
    fn disabled_routes_through_the_plain_path_bit_identically() {
        let ds = ds();
        let spec3 = spec(AlgoKind::DiscoS, 3);
        let (a, recoveries) = run_spec_maybe_elastic(&ds, &spec3, &ElasticSpec::none());
        assert_eq!(recoveries, 0);
        let b = run_spec(&ds, &spec3);
        assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits());
        assert_eq!(a.stats, b.stats);
        for (x, y) in a.w.iter().zip(b.w.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn second_kill_in_a_later_epoch_reforms_again() {
        let ds = ds();
        let spec4 = spec(AlgoKind::Gd, 4);
        let mut es = ElasticSpec::on();
        // Rank numbering is per-epoch: after kill@1:3 the world is 0..3,
        // and kill@3:2 targets the re-numbered rank 2.
        es.plan = FaultPlan::parse("kill@1:3,kill@3:2").unwrap();
        let (result, recoveries) = run_spec_elastic(&ds, &spec4, &es);
        assert_eq!(recoveries, 2);
        assert_eq!(result.node_ops.len(), 2);
    }

    #[test]
    fn min_world_is_enforced() {
        let ds = ds();
        let spec2 = spec(AlgoKind::Gd, 2);
        let mut es = ElasticSpec::on();
        es.min_world = 2;
        es.plan = FaultPlan::parse("kill@1:1").unwrap();
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_spec_elastic(&ds, &spec2, &es);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("below min world"), "got: {msg}");
    }
}
