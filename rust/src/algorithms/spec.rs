//! Declarative run specification — the single artifact every entrypoint
//! constructs a run from.
//!
//! [`RunSpec`] replaces the monolithic flat [`RunConfig`] with typed
//! sub-structs:
//!
//! * [`AlgoParams`] — which method, carrying *only* that method's knobs
//!   (the flat config kept `dane_eta` next to `tau` for every algorithm;
//!   the enum makes "which knob belongs to whom" a type-level fact);
//! * [`DataSpec`] — registry dataset name + down-scale factor;
//! * [`SimSpec`] — cluster shape and simulation knobs (m, seed, α–β cost
//!   model, compute model, heterogeneity, tracing);
//! * [`StopSpec`] — the composable stop policy: gradient tolerance ∧ outer
//!   cap ∧ optional simulated-time budget ∧ optional communication-round
//!   budget.
//!
//! Defaults follow the paper's §5 settings ([`RunSpec::new`]); the JSON
//! round-trip ([`RunSpec::to_json_string`] / [`RunSpec::from_json_str`])
//! lets `disco run --spec run.json`, `disco-node`, `disco-figures`, and
//! the benches all drive the same run from one file. `f64` knobs survive
//! the round trip bit-exactly (shortest-round-trip formatting; non-finite
//! values are encoded as strings since JSON has no `inf`).
//!
//! [`RunConfig::to_spec`] / [`RunSpec::to_config`] bridge the legacy
//! surface; the old run-to-completion entrypoints are thin wrappers over
//! the spec + [`Session`](crate::algorithms::session::Session) path.
//!
//! # Example
//!
//! ```
//! use disco::algorithms::{AlgoKind, RunSpec};
//! use disco::loss::LossKind;
//!
//! let spec = RunSpec::new(AlgoKind::DiscoF, LossKind::Logistic, 1e-4);
//! let json = spec.to_json_string();
//! let back = RunSpec::from_json_str(&json).unwrap();
//! assert_eq!(spec, back);
//! ```

use crate::algorithms::algorithm::Algorithm;
use crate::algorithms::{cocoa, dane, disco_f, disco_s, gd, AlgoKind, RunConfig};
use crate::data::{registry, Dataset};
use crate::loss::LossKind;
use crate::net::{Cluster, CollectiveAlgo, Collectives, ComputeModel, CostModel, StragglerConfig};
use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// The one gradient-tolerance default, stated once. The paper's Figure 3
/// plots runs down to ‖∇f‖ ≈ 1e-8; both the CLI and [`RunConfig::new`]
/// now share this value (the seed code had 1e-9 in the library default
/// and 1e-8 on the CLI — a drift this constant removes).
pub const GRAD_TOL_DEFAULT: f64 = 1e-8;

/// Knobs of the inexact damped Newton family (DiSCO-S / DiSCO-F /
/// original DiSCO). Defaults are the paper's §5.2 settings.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiscoParams {
    /// Preconditioner sample count τ (paper default 100).
    pub tau: usize,
    /// Preconditioner damping μ (paper: 1e-2).
    pub mu: f64,
    /// PCG forcing factor: ε_k = pcg_beta·‖∇f(w_k)‖.
    pub pcg_beta: f64,
    /// PCG steps cap per outer iteration.
    pub max_pcg: usize,
    /// Fraction of samples used for Hessian-vector products (Fig. 5;
    /// 1.0 = exact Hessian).
    pub hessian_fraction: f64,
    /// DiSCO-F only: balance feature shards by modeled row work instead of
    /// feature count (no-op for the sample-partitioned variants).
    pub balanced_partition: bool,
}

impl Default for DiscoParams {
    fn default() -> Self {
        Self {
            tau: 100,
            mu: 1e-2,
            pcg_beta: 1.0 / 20.0,
            max_pcg: 500,
            hessian_fraction: 1.0,
            balanced_partition: false,
        }
    }
}

/// Original DiSCO's master-only SAG preconditioner solve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SagParams {
    /// Inner solve tolerance factor (relative to ‖r‖).
    pub inner_tol: f64,
    /// Epoch cap per preconditioner solve.
    pub max_epochs: usize,
}

impl Default for SagParams {
    fn default() -> Self {
        Self { inner_tol: 0.05, max_epochs: 30 }
    }
}

/// DANE's subproblem knobs (paper Eq. (1); SAG local solver).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DaneParams {
    /// Gradient weight η.
    pub eta: f64,
    /// Subproblem regularization μ.
    pub mu: f64,
    /// SAG epochs per local solve.
    pub local_epochs: usize,
}

impl Default for DaneParams {
    fn default() -> Self {
        Self { eta: 1.0, mu: 1e-2, local_epochs: 3 }
    }
}

/// CoCoA+ knobs (SDCA local solver, σ′ = m "adding" variant).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CocoaParams {
    /// SDCA epochs per outer iteration (the paper's H).
    pub local_epochs: usize,
}

impl Default for CocoaParams {
    fn default() -> Self {
        Self { local_epochs: 3 }
    }
}

/// Which algorithm runs, with exactly its knobs.
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoParams {
    /// Feature-partitioned DiSCO (the paper's contribution).
    DiscoF(DiscoParams),
    /// Sample-partitioned DiSCO with Woodbury preconditioning.
    DiscoS(DiscoParams),
    /// Original DiSCO: Woodbury replaced by a master-only SAG inner solve.
    DiscoOrig(DiscoParams, SagParams),
    Dane(DaneParams),
    CocoaPlus(CocoaParams),
    Gd,
}

impl AlgoParams {
    /// Paper-default parameters for `kind`.
    pub fn for_kind(kind: AlgoKind) -> AlgoParams {
        match kind {
            AlgoKind::DiscoF => AlgoParams::DiscoF(DiscoParams::default()),
            AlgoKind::DiscoS => AlgoParams::DiscoS(DiscoParams::default()),
            AlgoKind::DiscoOrig => {
                AlgoParams::DiscoOrig(DiscoParams::default(), SagParams::default())
            }
            AlgoKind::Dane => AlgoParams::Dane(DaneParams::default()),
            AlgoKind::CocoaPlus => AlgoParams::CocoaPlus(CocoaParams::default()),
            AlgoKind::Gd => AlgoParams::Gd,
        }
    }

    pub fn kind(&self) -> AlgoKind {
        match self {
            AlgoParams::DiscoF(_) => AlgoKind::DiscoF,
            AlgoParams::DiscoS(_) => AlgoKind::DiscoS,
            AlgoParams::DiscoOrig(..) => AlgoKind::DiscoOrig,
            AlgoParams::Dane(_) => AlgoKind::Dane,
            AlgoParams::CocoaPlus(_) => AlgoKind::CocoaPlus,
            AlgoParams::Gd => AlgoKind::Gd,
        }
    }

    /// The Newton-family knobs when this is a DiSCO variant.
    pub fn disco(&self) -> Option<&DiscoParams> {
        match self {
            AlgoParams::DiscoF(p) | AlgoParams::DiscoS(p) | AlgoParams::DiscoOrig(p, _) => Some(p),
            _ => None,
        }
    }

    pub fn disco_mut(&mut self) -> Option<&mut DiscoParams> {
        match self {
            AlgoParams::DiscoF(p) | AlgoParams::DiscoS(p) | AlgoParams::DiscoOrig(p, _) => Some(p),
            _ => None,
        }
    }

    /// Resolve the solver implementation — the *only* algorithm dispatch
    /// in the crate; everything downstream goes through the object-safe
    /// [`Algorithm`] / [`AlgorithmNode`](crate::algorithms::AlgorithmNode)
    /// surface.
    pub fn algorithm<C: Collectives>(&self) -> Box<dyn Algorithm<C>> {
        match self {
            AlgoParams::DiscoF(_) => Box::new(disco_f::DiscoF),
            AlgoParams::DiscoS(_) => Box::new(disco_s::DiscoS),
            AlgoParams::DiscoOrig(..) => Box::new(disco_s::DiscoOrig),
            AlgoParams::Dane(_) => Box::new(dane::Dane),
            AlgoParams::CocoaPlus(_) => Box::new(cocoa::CocoaPlus),
            AlgoParams::Gd => Box::new(gd::Gd),
        }
    }
}

/// Which dataset a spec-driven binary loads ([`DataSpec::load`]). Library
/// callers that already hold a [`Dataset`] pass it directly and this field
/// is ignored (`name` may stay empty).
#[derive(Clone, Debug, PartialEq)]
pub struct DataSpec {
    /// Registry name (see `disco datasets`); empty = caller-supplied.
    pub name: String,
    /// Down-scale factor (1 = full registry size).
    pub scale: usize,
    /// Shard-store directory (`disco ingest`). When set, the dataset is
    /// opened out-of-core from its shard files instead of the registry;
    /// a non-empty `name` then acts as a cross-check against the store's
    /// manifest.
    pub store: Option<String>,
}

impl DataSpec {
    /// A spec whose dataset the caller supplies in code.
    pub fn inline() -> Self {
        Self { name: String::new(), scale: 1, store: None }
    }

    pub fn named(name: &str) -> Self {
        Self { name: name.to_string(), scale: 1, store: None }
    }

    /// Load from the registry (None for unknown / empty names). Store
    /// errors are silently mapped to None here — spec-driven binaries use
    /// [`DataSpec::load_checked`] so a corrupt store aborts with its
    /// actual IO error instead of a generic "unknown dataset".
    pub fn load(&self) -> Option<Dataset> {
        self.load_checked().ok().flatten()
    }

    /// Like [`DataSpec::load`], but store problems (missing directory,
    /// checksum mismatch, manifest/name disagreement) surface as errors.
    pub fn load_checked(&self) -> Result<Option<Dataset>, String> {
        if let Some(dir) = &self.store {
            if self.scale > 1 {
                return Err(format!(
                    "--scale {} cannot be applied to a shard store; re-ingest the scaled \
                     dataset instead",
                    self.scale
                ));
            }
            let ds = crate::store::open_dataset(std::path::Path::new(dir))
                .map_err(|e| format!("cannot open store '{dir}': {e}"))?;
            if !self.name.is_empty() && ds.name != self.name {
                return Err(format!(
                    "store '{dir}' holds dataset '{}', but the spec names '{}'",
                    ds.name, self.name
                ));
            }
            return Ok(Some(ds));
        }
        if self.name.is_empty() {
            return Ok(None);
        }
        Ok(if self.scale <= 1 {
            registry::load(&self.name)
        } else {
            registry::load_scaled(&self.name, self.scale)
        })
    }
}

/// Cluster shape + simulation knobs (everything that is about *how* the
/// run executes rather than *what* is optimized).
#[derive(Clone, Debug, PartialEq)]
pub struct SimSpec {
    /// Number of nodes m.
    pub m: usize,
    pub seed: u64,
    /// α–β network cost model (incl. the collective algorithm).
    pub cost: CostModel,
    /// How node compute advances the simulated clock; `Modeled` makes
    /// seeded runs bit-identical.
    pub compute: ComputeModel,
    /// Intra-node threads for the HVP kernels (1 = serial).
    pub node_threads: usize,
    /// Per-node relative compute speeds (empty = homogeneous fleet).
    pub speeds: Vec<f64>,
    /// Size shards proportionally to `speeds` so work ÷ speed equalizes.
    pub weighted_partition: bool,
    /// Deterministic seeded slowdown episodes.
    pub straggler: Option<StragglerConfig>,
    /// Record the per-node activity trace (Fig. 2).
    pub trace: bool,
    /// Record the structured event stream (spans, counters, incidents) —
    /// unpriced and bit-invisible to the run itself.
    pub events: bool,
    /// Split-phase PCG: overlap the HVP collective of block k with the
    /// compute of block k+1 (DiSCO-S/F, sparse shards only). Off ⇒ the
    /// blocking code path, bit-identical to pre-overlap runs.
    pub overlap: bool,
}

impl Default for SimSpec {
    fn default() -> Self {
        Self {
            m: 4, // the paper's 4 EC2 instances
            seed: 42,
            cost: CostModel::default(),
            compute: ComputeModel::Measured,
            node_threads: 1,
            speeds: Vec::new(),
            weighted_partition: false,
            straggler: None,
            trace: false,
            events: false,
            overlap: false,
        }
    }
}

impl SimSpec {
    /// Thread cluster honoring every simulation knob — the single
    /// construction path for shm runs.
    pub fn cluster(&self) -> Cluster {
        let mut c = Cluster::new(self.m)
            .with_cost(self.cost)
            .with_trace(self.trace)
            .with_obs(self.events)
            .with_compute(self.compute);
        if !self.speeds.is_empty() {
            c = c.with_speeds(self.speeds.clone());
        }
        if let Some(s) = self.straggler {
            c = c.with_straggler(s);
        }
        c
    }

    /// Speeds slice when a weighted partition was requested (None ⇒ use
    /// the uniform split).
    pub fn partition_speeds(&self) -> Option<&[f64]> {
        if self.weighted_partition && !self.speeds.is_empty() {
            Some(&self.speeds)
        } else {
            None
        }
    }
}

/// Composable stop policy, evaluated by the
/// [`Session`](crate::algorithms::session::Session) driver after every
/// outer iteration. All configured conditions are OR-ed: the run stops at
/// the first one that fires.
#[derive(Clone, Debug, PartialEq)]
pub struct StopSpec {
    /// Stop when ‖∇f‖ ≤ grad_tol (checked inside the step, before the
    /// inner solve — the converged iterate does no extra work).
    pub grad_tol: f64,
    /// Outer-iteration cap.
    pub max_outer: usize,
    /// Simulated-seconds budget (None = unbounded). Enforcing it costs one
    /// *free* metrics round per outer iteration so every rank agrees.
    pub max_sim_seconds: Option<f64>,
    /// Vector-communication-round budget (None = unbounded). Free to
    /// enforce: the round counters are identical on every rank.
    pub max_rounds: Option<u64>,
}

impl Default for StopSpec {
    fn default() -> Self {
        Self {
            grad_tol: GRAD_TOL_DEFAULT,
            max_outer: 100,
            max_sim_seconds: None,
            max_rounds: None,
        }
    }
}

/// Default trigger for adaptive re-partitioning: re-cut once the
/// windowed per-rank busy seconds differ by ≥ 20 % (max/min across the
/// fleet). Below that, a re-cut's setup + re-shard cost outweighs the
/// projected win on the short windows it is measured over.
pub const REPARTITION_THRESHOLD_DEFAULT: f64 = 1.2;

/// How an adaptive re-cut chooses its shard-sizing weights (see
/// [`crate::algorithms::repartition::Repartitioner`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepartitionPolicy {
    /// Weights = measured shard work ÷ windowed busy seconds per rank —
    /// the effective speeds the fleet *demonstrated*. The paper assumes
    /// speeds are known up front; this discovers them mid-run.
    Measured,
    /// Weights = `sim.speeds` (oracle re-cut from the configured speeds;
    /// an ablation/diagnostic of the measured estimator).
    Known,
}

impl RepartitionPolicy {
    pub fn parse(s: &str) -> Option<RepartitionPolicy> {
        match s {
            "measured" => Some(RepartitionPolicy::Measured),
            "known" => Some(RepartitionPolicy::Known),
            _ => None,
        }
    }
}

/// Adaptive mid-run re-partitioning knobs. Like
/// [`CheckpointPlan`](crate::algorithms::session::CheckpointPlan) this is
/// a property of *how a run is driven*, not of the problem being solved,
/// so it rides beside [`RunSpec`] (and outside its JSON) into
/// [`run_spec_full`](crate::algorithms::session::run_spec_full) /
/// `run_over_spec`.
///
/// With `every = None` the trigger is **disabled** and the driver adds
/// zero communication and zero branching — a run is bit-identical to a
/// plain [`Session`](crate::algorithms::session::Session) run
/// (test-enforced).
#[derive(Clone, Debug, PartialEq)]
pub struct RepartitionSpec {
    /// Observation window: check the windowed busy-seconds imbalance
    /// every this many outer iterations (None = disabled).
    pub every: Option<usize>,
    /// Re-cut only when the windowed busy max/min across ranks reaches
    /// this ratio (≥ 1; [`REPARTITION_THRESHOLD_DEFAULT`]).
    pub threshold: f64,
    pub policy: RepartitionPolicy,
}

impl Default for RepartitionSpec {
    fn default() -> Self {
        Self::none()
    }
}

impl RepartitionSpec {
    /// Trigger disabled: the driver is a plain Session run.
    pub fn none() -> Self {
        Self {
            every: None,
            threshold: REPARTITION_THRESHOLD_DEFAULT,
            policy: RepartitionPolicy::Measured,
        }
    }

    /// Measured-speed re-cuts every `window` outer iterations at the
    /// given imbalance threshold.
    pub fn every(window: usize, threshold: f64) -> Self {
        assert!(window >= 1, "observation window is at least one iteration");
        assert!(threshold >= 1.0, "imbalance threshold is a max/min ratio ≥ 1");
        Self {
            every: Some(window),
            threshold,
            policy: RepartitionPolicy::Measured,
        }
    }

    pub fn enabled(&self) -> bool {
        self.every.is_some()
    }

    /// Declare the adaptive-load-balancing flags shared by the `disco`
    /// and `disco-node` binaries; parse them back with
    /// [`RepartitionSpec::from_args`].
    pub fn with_flags(args: Args) -> Args {
        args.opt(
            "repartition-every",
            None,
            "adaptive balancing: re-check measured speeds every N outer iterations (0 = off)",
        )
        .opt(
            "repartition-threshold",
            Some("1.2"),
            "re-cut when windowed busy seconds max/min across ranks reaches this ratio",
        )
        .opt(
            "repartition-policy",
            Some("measured"),
            "re-cut weights: measured (shard work ÷ busy time) | known (sim speeds)",
        )
    }

    /// Build the spec from [`RepartitionSpec::with_flags`]
    /// (`--repartition-every 0` and an absent flag both mean disabled).
    pub fn from_args(args: &Args) -> Result<RepartitionSpec, String> {
        let mut rp = RepartitionSpec::none();
        if args.provided("repartition-every") {
            let every = args.get_usize("repartition-every").map_err(|e| e.to_string())?;
            rp.every = if every == 0 { None } else { Some(every) };
        }
        if args.provided("repartition-threshold") {
            rp.threshold = args.get_f64("repartition-threshold").map_err(|e| e.to_string())?;
            if rp.threshold.is_nan() || rp.threshold < 1.0 {
                return Err("--repartition-threshold is a max/min ratio and must be ≥ 1".into());
            }
        }
        if args.provided("repartition-policy") {
            let name = args.req("repartition-policy").map_err(|e| e.to_string())?;
            rp.policy = RepartitionPolicy::parse(&name)
                .ok_or_else(|| format!("bad --repartition-policy '{name}' (measured | known)"))?;
        }
        Ok(rp)
    }
}

/// What a planned fault does to its target rank.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// The rank departs at the boundary; survivors re-form at world − 1.
    Kill,
    /// The rank's clock is advanced by this many *priced* simulated
    /// seconds at the boundary (a transient stall, not a death).
    Delay(f64),
    /// A fresh worker joins at the boundary (shm driver spawns a node;
    /// under TCP real joiner processes arrive on their own, so the event
    /// is ignored there).
    Join,
}

/// One planned fault: at the *start* of outer iteration `at_outer`
/// (0-based, counted like `--save-at`), `action` happens to `rank`
/// (current-epoch numbering; ignored for `Join`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at_outer: usize,
    pub rank: usize,
    pub action: FaultAction,
}

/// Deterministic fault-injection schedule. Every rank holds the identical
/// plan (SPMD), so planned kills fire without waiting for socket
/// symptoms: the target departs cleanly and the survivors raise the
/// matching typed fault immediately — bit-deterministic on both
/// transports under the modeled clock.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self { events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events scheduled for the boundary at the start of outer `k`.
    pub fn at(&self, outer: usize) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter().filter(move |e| e.at_outer == outer)
    }

    /// Parse the `--fault` flag: comma-separated events,
    /// `kill@K:R | delay@K:R:SECS | join@K`
    /// (K = outer iteration, R = rank). Example:
    /// `kill@6:2,delay@4:1:0.5,join@8`.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for item in s.split(',').map(str::trim).filter(|i| !i.is_empty()) {
            let (verb, rest) = item
                .split_once('@')
                .ok_or_else(|| format!("bad fault '{item}': expected action@outer[:…]"))?;
            let parts: Vec<&str> = rest.split(':').collect();
            let outer = |p: &str| -> Result<usize, String> {
                p.parse().map_err(|_| format!("bad fault '{item}': '{p}' is not an iteration"))
            };
            let rank = |p: &str| -> Result<usize, String> {
                p.parse().map_err(|_| format!("bad fault '{item}': '{p}' is not a rank"))
            };
            let ev = match (verb, parts.as_slice()) {
                ("kill", [k, r]) => FaultEvent {
                    at_outer: outer(k)?,
                    rank: rank(r)?,
                    action: FaultAction::Kill,
                },
                ("delay", [k, r, secs]) => FaultEvent {
                    at_outer: outer(k)?,
                    rank: rank(r)?,
                    action: FaultAction::Delay(secs.parse().map_err(|_| {
                        format!("bad fault '{item}': '{secs}' is not a duration")
                    })?),
                },
                ("join", [k]) => FaultEvent {
                    at_outer: outer(k)?,
                    rank: 0,
                    action: FaultAction::Join,
                },
                _ => {
                    return Err(format!(
                        "bad fault '{item}': expected kill@K:R, delay@K:R:SECS, or join@K"
                    ))
                }
            };
            if ev.action == FaultAction::Kill && ev.rank == 0 {
                return Err(format!(
                    "bad fault '{item}': rank 0 hosts the rendezvous and cannot be killed"
                ));
            }
            events.push(ev);
        }
        events.sort_by_key(|e| e.at_outer);
        Ok(FaultPlan { events })
    }
}

/// Elastic-fleet knobs. Like [`RepartitionSpec`] this describes *how a
/// run is driven*, not the problem being solved, so it rides beside
/// [`RunSpec`] into the drivers. With `enabled = false` the driver adds
/// zero communication and zero branching — a run is bit-identical to a
/// plain [`Session`](crate::algorithms::session::Session) run on both
/// transports (test-enforced).
#[derive(Clone, Debug, PartialEq)]
pub struct ElasticSpec {
    pub enabled: bool,
    /// Abort (fail-fast) if a reform leaves fewer than this many ranks.
    pub min_world: usize,
    /// Wall-clock window a reform waits for survivors/joiners to
    /// re-rendezvous (TCP).
    pub rejoin_window_secs: f64,
    /// Give up after this many recoveries in one run.
    pub max_recoveries: usize,
    /// Base delay of the seeded exponential-backoff reconnect loop (TCP).
    pub backoff_secs: f64,
    /// Wall-clock sleep per outer boundary, milliseconds (0 = off). Gives
    /// external chaos (SIGKILL, joiners) a window to land mid-run in
    /// tests/CI; the simulated clock never sees it.
    pub pace_ms: u64,
    /// This process is a fresh joiner: dial the rendezvous and wait for
    /// admission instead of holding a rank (TCP only).
    pub join: bool,
    /// Planned, deterministic faults.
    pub plan: FaultPlan,
    /// Seed for the reconnect jitter stream.
    pub fault_seed: u64,
}

impl Default for ElasticSpec {
    fn default() -> Self {
        Self::none()
    }
}

impl ElasticSpec {
    /// Elasticity off: the driver is a plain Session run.
    pub fn none() -> Self {
        Self {
            enabled: false,
            min_world: 1,
            rejoin_window_secs: 5.0,
            max_recoveries: 8,
            backoff_secs: 0.05,
            pace_ms: 0,
            join: false,
            plan: FaultPlan::none(),
            fault_seed: 0x5EED_E1A5_71C0_0000,
        }
    }

    /// Elasticity on with the defaults.
    pub fn on() -> Self {
        Self { enabled: true, ..Self::none() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled || self.join || !self.plan.is_empty()
    }

    /// Declare the elastic-fleet flags shared by the `disco` and
    /// `disco-node` binaries; parse them back with
    /// [`ElasticSpec::from_args`].
    pub fn with_flags(args: Args) -> Args {
        args.switch("elastic", "survive membership changes: re-form in epochs instead of aborting")
            .opt("elastic-min-world", Some("1"), "abort if a re-form leaves fewer ranks than this")
            .opt(
                "elastic-rejoin-window",
                Some("5"),
                "seconds a re-form waits for survivors/joiners to re-rendezvous",
            )
            .opt("elastic-max-recoveries", Some("8"), "give up after this many recoveries")
            .opt(
                "elastic-backoff",
                Some("0.05"),
                "base seconds of the seeded exponential-backoff reconnect loop",
            )
            .opt(
                "elastic-pace-ms",
                Some("0"),
                "wall-clock sleep per outer boundary, ms (lets external chaos land mid-run)",
            )
            .switch("elastic-join", "join a running elastic fleet instead of holding a rank")
            .opt(
                "fault",
                None,
                "deterministic fault plan: kill@K:R,delay@K:R:SECS,join@K (comma-separated)",
            )
            .opt("fault-seed", None, "seed for the reconnect jitter stream")
    }

    /// Build the spec from [`ElasticSpec::with_flags`]. `--elastic-join`
    /// and `--fault` imply `--elastic`.
    pub fn from_args(args: &Args) -> Result<ElasticSpec, String> {
        let mut es = ElasticSpec::none();
        es.enabled = args.flag("elastic");
        es.join = args.flag("elastic-join");
        if args.provided("fault") {
            let plan = args.req("fault").map_err(|e| e.to_string())?;
            es.plan = FaultPlan::parse(&plan)?;
        }
        if args.provided("elastic-min-world") {
            es.min_world = args.get_usize("elastic-min-world").map_err(|e| e.to_string())?;
            if es.min_world == 0 {
                return Err("--elastic-min-world must be ≥ 1".into());
            }
        }
        if args.provided("elastic-rejoin-window") {
            es.rejoin_window_secs =
                args.get_f64("elastic-rejoin-window").map_err(|e| e.to_string())?;
            if !es.rejoin_window_secs.is_finite() || es.rejoin_window_secs <= 0.0 {
                return Err("--elastic-rejoin-window must be positive".into());
            }
        }
        if args.provided("elastic-max-recoveries") {
            es.max_recoveries =
                args.get_usize("elastic-max-recoveries").map_err(|e| e.to_string())?;
        }
        if args.provided("elastic-backoff") {
            es.backoff_secs = args.get_f64("elastic-backoff").map_err(|e| e.to_string())?;
            if !es.backoff_secs.is_finite() || es.backoff_secs < 0.0 {
                return Err("--elastic-backoff must be ≥ 0".into());
            }
        }
        if args.provided("elastic-pace-ms") {
            es.pace_ms = args.get_u64("elastic-pace-ms").map_err(|e| e.to_string())?;
        }
        if args.provided("fault-seed") {
            es.fault_seed = args.get_u64("fault-seed").map_err(|e| e.to_string())?;
        }
        Ok(es)
    }

    /// The transport-layer membership knobs this spec implies (TCP).
    pub fn tcp_options(&self) -> crate::net::ElasticOptions {
        crate::net::ElasticOptions {
            rejoin_window: std::time::Duration::from_secs_f64(self.rejoin_window_secs),
            min_world: self.min_world,
            backoff: std::time::Duration::from_secs_f64(self.backoff_secs),
            seed: self.fault_seed,
        }
    }
}

/// Full declarative run description. See the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    pub algo: AlgoParams,
    pub loss: LossKind,
    /// ℓ2 regularization λ.
    pub lambda: f64,
    pub data: DataSpec,
    pub sim: SimSpec,
    pub stop: StopSpec,
}

impl RunSpec {
    /// Paper-§5 defaults for `kind` (m = 4, τ = 100, μ = 1e-2,
    /// β = 1/20, grad_tol = [`GRAD_TOL_DEFAULT`], 100 outer iterations,
    /// binomial-tree α–β pricing, measured compute).
    pub fn new(kind: AlgoKind, loss: LossKind, lambda: f64) -> RunSpec {
        RunSpec {
            algo: AlgoParams::for_kind(kind),
            loss,
            lambda,
            data: DataSpec::inline(),
            sim: SimSpec::default(),
            stop: StopSpec::default(),
        }
    }

    pub fn kind(&self) -> AlgoKind {
        self.algo.kind()
    }

    // -- small builder conveniences (field access works too) --------------

    pub fn with_m(mut self, m: usize) -> Self {
        self.sim.m = m;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.sim.seed = seed;
        self
    }

    pub fn with_compute(mut self, compute: ComputeModel) -> Self {
        self.sim.compute = compute;
        self
    }

    pub fn with_cost(mut self, cost: CostModel) -> Self {
        self.sim.cost = cost;
        self
    }

    pub fn with_grad_tol(mut self, tol: f64) -> Self {
        self.stop.grad_tol = tol;
        self
    }

    pub fn with_max_outer(mut self, cap: usize) -> Self {
        self.stop.max_outer = cap;
        self
    }

    pub fn with_trace(mut self, on: bool) -> Self {
        self.sim.trace = on;
        self
    }

    pub fn with_data(mut self, name: &str, scale: usize) -> Self {
        self.data = DataSpec { name: name.to_string(), scale: scale.max(1), store: None };
        self
    }

    /// Structural sanity checks shared by every entrypoint.
    pub fn validate(&self) -> Result<(), String> {
        if self.sim.m < 1 {
            return Err("sim.m must be at least 1".into());
        }
        if !self.sim.speeds.is_empty() && self.sim.speeds.len() != self.sim.m {
            return Err(format!(
                "sim.speeds has {} entries for m = {}",
                self.sim.speeds.len(),
                self.sim.m
            ));
        }
        if self.sim.speeds.iter().any(|s| !s.is_finite() || *s <= 0.0) {
            return Err("sim.speeds must be positive and finite".into());
        }
        if !(self.lambda.is_finite() && self.lambda >= 0.0) {
            return Err("lambda must be finite and ≥ 0".into());
        }
        if !(self.stop.grad_tol.is_finite() && self.stop.grad_tol >= 0.0) {
            return Err("stop.grad_tol must be finite and ≥ 0".into());
        }
        if let Some(p) = self.algo.disco() {
            if !(p.hessian_fraction > 0.0 && p.hessian_fraction <= 1.0) {
                return Err("hessian_fraction must be in (0, 1]".into());
            }
        }
        if let Some(s) = self.stop.max_sim_seconds {
            if !(s.is_finite() && s > 0.0) {
                return Err("stop.max_sim_seconds must be positive and finite".into());
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// RunConfig bridge
// ---------------------------------------------------------------------------

impl RunConfig {
    /// Lift the flat legacy config into the typed spec. Knobs that don't
    /// belong to `self.algo` (e.g. `tau` for DANE) are dropped — they were
    /// dead weight in the flat struct.
    pub fn to_spec(&self) -> RunSpec {
        let disco = DiscoParams {
            tau: self.tau,
            mu: self.mu,
            pcg_beta: self.pcg_beta,
            max_pcg: self.max_pcg,
            hessian_fraction: self.hessian_fraction,
            balanced_partition: self.balanced_partition,
        };
        let algo = match self.algo {
            AlgoKind::DiscoF => AlgoParams::DiscoF(disco),
            AlgoKind::DiscoS => AlgoParams::DiscoS(disco),
            AlgoKind::DiscoOrig => AlgoParams::DiscoOrig(
                disco,
                SagParams {
                    inner_tol: self.sag_inner_tol,
                    max_epochs: self.sag_max_epochs,
                },
            ),
            AlgoKind::Dane => AlgoParams::Dane(DaneParams {
                eta: self.dane_eta,
                mu: self.mu,
                local_epochs: self.local_epochs,
            }),
            AlgoKind::CocoaPlus => AlgoParams::CocoaPlus(CocoaParams {
                local_epochs: self.local_epochs,
            }),
            AlgoKind::Gd => AlgoParams::Gd,
        };
        RunSpec {
            algo,
            loss: self.loss,
            lambda: self.lambda,
            data: DataSpec::inline(),
            sim: SimSpec {
                m: self.m,
                seed: self.seed,
                cost: self.cost,
                compute: self.compute,
                node_threads: self.node_threads,
                speeds: self.speeds.clone(),
                weighted_partition: self.weighted_partition,
                straggler: self.straggler,
                trace: self.trace,
                events: false,
                overlap: false,
            },
            stop: StopSpec {
                grad_tol: self.grad_tol,
                max_outer: self.max_outer,
                max_sim_seconds: None,
                max_rounds: None,
            },
        }
    }
}

impl RunSpec {
    /// Flatten back into the legacy config (compat for code that still
    /// reads flat fields, e.g. the XLA runtime path). Knobs foreign to the
    /// spec's algorithm take their paper defaults.
    pub fn to_config(&self) -> RunConfig {
        let mut c = RunConfig::new(self.kind(), self.loss, self.lambda);
        c.m = self.sim.m;
        c.seed = self.sim.seed;
        c.cost = self.sim.cost;
        c.compute = self.sim.compute;
        c.node_threads = self.sim.node_threads;
        c.speeds = self.sim.speeds.clone();
        c.weighted_partition = self.sim.weighted_partition;
        c.straggler = self.sim.straggler;
        c.trace = self.sim.trace;
        c.grad_tol = self.stop.grad_tol;
        c.max_outer = self.stop.max_outer;
        if let Some(p) = self.algo.disco() {
            c.tau = p.tau;
            c.mu = p.mu;
            c.pcg_beta = p.pcg_beta;
            c.max_pcg = p.max_pcg;
            c.hessian_fraction = p.hessian_fraction;
            c.balanced_partition = p.balanced_partition;
        }
        match &self.algo {
            AlgoParams::DiscoOrig(_, sag) => {
                c.sag_inner_tol = sag.inner_tol;
                c.sag_max_epochs = sag.max_epochs;
            }
            AlgoParams::Dane(d) => {
                c.dane_eta = d.eta;
                c.mu = d.mu;
                c.local_epochs = d.local_epochs;
            }
            AlgoParams::CocoaPlus(cp) => {
                c.local_epochs = cp.local_epochs;
            }
            _ => {}
        }
        c
    }
}

// ---------------------------------------------------------------------------
// JSON round trip
// ---------------------------------------------------------------------------

/// Emit an `f64` as a JSON number; non-finite values (the zero-cost model
/// uses β = ∞) become the strings `"inf"` / `"-inf"` / `"nan"`.
fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        json::s("nan")
    } else if x > 0.0 {
        json::s("inf")
    } else {
        json::s("-inf")
    }
}

fn take_f64(v: &Json, key: &str) -> Result<f64, String> {
    match v.get(key) {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            other => other
                .parse::<f64>()
                .map_err(|_| format!("'{key}': bad float '{other}'")),
        },
        Json::Null => Err(format!("missing key '{key}'")),
        _ => Err(format!("'{key}': expected a number")),
    }
}

fn take_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| format!("'{key}': expected a non-negative integer"))
}

fn take_bool(v: &Json, key: &str) -> Result<bool, String> {
    match v.get(key) {
        Json::Bool(b) => Ok(*b),
        _ => Err(format!("'{key}': expected a boolean")),
    }
}

fn take_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    v.get(key)
        .as_str()
        .ok_or_else(|| format!("'{key}': expected a string"))
}

/// Seeds are emitted as decimal strings: the JSON number path goes through
/// `f64`, which would silently round seeds above 2⁵³.
fn take_u64(v: &Json, key: &str) -> Result<u64, String> {
    match v.get(key) {
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| format!("'{key}': bad u64 '{s}'")),
        Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9.007_199_254_740_992e15 => {
            Ok(*x as u64)
        }
        _ => Err(format!("'{key}': expected a u64 (string or integer)")),
    }
}

impl RunSpec {
    pub fn to_json(&self) -> Json {
        let mut algo: Vec<(&str, Json)> = vec![("kind", json::s(self.kind().name()))];
        if let Some(p) = self.algo.disco() {
            algo.push(("tau", json::num(p.tau as f64)));
            algo.push(("mu", jnum(p.mu)));
            algo.push(("pcg_beta", jnum(p.pcg_beta)));
            algo.push(("max_pcg", json::num(p.max_pcg as f64)));
            algo.push(("hessian_fraction", jnum(p.hessian_fraction)));
            algo.push(("balanced_partition", Json::Bool(p.balanced_partition)));
        }
        match &self.algo {
            AlgoParams::DiscoOrig(_, sag) => {
                algo.push(("sag_inner_tol", jnum(sag.inner_tol)));
                algo.push(("sag_max_epochs", json::num(sag.max_epochs as f64)));
            }
            AlgoParams::Dane(d) => {
                algo.push(("eta", jnum(d.eta)));
                algo.push(("mu", jnum(d.mu)));
                algo.push(("local_epochs", json::num(d.local_epochs as f64)));
            }
            AlgoParams::CocoaPlus(cp) => {
                algo.push(("local_epochs", json::num(cp.local_epochs as f64)));
            }
            _ => {}
        }
        let compute = match self.sim.compute {
            ComputeModel::Measured => json::obj(vec![("kind", json::s("measured"))]),
            ComputeModel::Modeled { flops_per_sec } => json::obj(vec![
                ("kind", json::s("modeled")),
                ("flops_per_sec", jnum(flops_per_sec)),
            ]),
        };
        let straggler = match self.sim.straggler {
            None => Json::Null,
            Some(s) => json::obj(vec![
                ("prob", jnum(s.prob)),
                ("slowdown", jnum(s.slowdown)),
                ("len", json::num(s.len as f64)),
                ("seed", json::s(&s.seed.to_string())),
            ]),
        };
        json::obj(vec![
            ("algo", json::obj(algo)),
            ("loss", json::s(self.loss.name())),
            ("lambda", jnum(self.lambda)),
            (
                "data",
                json::obj(vec![
                    ("name", json::s(&self.data.name)),
                    ("scale", json::num(self.data.scale as f64)),
                    (
                        "store",
                        self.data.store.as_deref().map_or(Json::Null, json::s),
                    ),
                ]),
            ),
            (
                "sim",
                json::obj(vec![
                    ("m", json::num(self.sim.m as f64)),
                    ("seed", json::s(&self.sim.seed.to_string())),
                    (
                        "cost",
                        json::obj(vec![
                            ("alpha", jnum(self.sim.cost.alpha)),
                            ("beta", jnum(self.sim.cost.beta)),
                            ("collective", json::s(self.sim.cost.algo.name())),
                        ]),
                    ),
                    ("compute", compute),
                    ("node_threads", json::num(self.sim.node_threads as f64)),
                    (
                        "speeds",
                        json::arr(self.sim.speeds.iter().map(|s| jnum(*s)).collect()),
                    ),
                    ("weighted_partition", Json::Bool(self.sim.weighted_partition)),
                    ("straggler", straggler),
                    ("trace", Json::Bool(self.sim.trace)),
                    ("events", Json::Bool(self.sim.events)),
                    ("overlap", Json::Bool(self.sim.overlap)),
                ]),
            ),
            (
                "stop",
                json::obj(vec![
                    ("grad_tol", jnum(self.stop.grad_tol)),
                    ("max_outer", json::num(self.stop.max_outer as f64)),
                    (
                        "max_sim_seconds",
                        self.stop.max_sim_seconds.map_or(Json::Null, jnum),
                    ),
                    (
                        "max_rounds",
                        self.stop
                            .max_rounds
                            .map_or(Json::Null, |r| json::s(&r.to_string())),
                    ),
                ]),
            ),
        ])
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_json(v: &Json) -> Result<RunSpec, String> {
        let a = v.get("algo");
        let kind_name = take_str(a, "kind")?;
        let kind =
            AlgoKind::parse(kind_name).ok_or_else(|| format!("unknown algo kind '{kind_name}'"))?;
        let disco = || -> Result<DiscoParams, String> {
            Ok(DiscoParams {
                tau: take_usize(a, "tau")?,
                mu: take_f64(a, "mu")?,
                pcg_beta: take_f64(a, "pcg_beta")?,
                max_pcg: take_usize(a, "max_pcg")?,
                hessian_fraction: take_f64(a, "hessian_fraction")?,
                balanced_partition: take_bool(a, "balanced_partition")?,
            })
        };
        let algo = match kind {
            AlgoKind::DiscoF => AlgoParams::DiscoF(disco()?),
            AlgoKind::DiscoS => AlgoParams::DiscoS(disco()?),
            AlgoKind::DiscoOrig => AlgoParams::DiscoOrig(
                disco()?,
                SagParams {
                    inner_tol: take_f64(a, "sag_inner_tol")?,
                    max_epochs: take_usize(a, "sag_max_epochs")?,
                },
            ),
            AlgoKind::Dane => AlgoParams::Dane(DaneParams {
                eta: take_f64(a, "eta")?,
                mu: take_f64(a, "mu")?,
                local_epochs: take_usize(a, "local_epochs")?,
            }),
            AlgoKind::CocoaPlus => AlgoParams::CocoaPlus(CocoaParams {
                local_epochs: take_usize(a, "local_epochs")?,
            }),
            AlgoKind::Gd => AlgoParams::Gd,
        };
        let loss = LossKind::parse(take_str(v, "loss")?)
            .ok_or_else(|| format!("unknown loss '{}'", take_str(v, "loss")?))?;
        let d = v.get("data");
        let data = DataSpec {
            name: take_str(d, "name")?.to_string(),
            scale: take_usize(d, "scale")?.max(1),
            // Lenient: absent in pre-store spec files ⇒ registry path.
            store: match d.get("store") {
                Json::Str(dir) => Some(dir.clone()),
                _ => None,
            },
        };
        let s = v.get("sim");
        let cost_v = s.get("cost");
        let collective = take_str(cost_v, "collective")?;
        let cost = CostModel {
            alpha: take_f64(cost_v, "alpha")?,
            beta: take_f64(cost_v, "beta")?,
            algo: CollectiveAlgo::parse(collective)
                .ok_or_else(|| format!("unknown collective algorithm '{collective}'"))?,
        };
        let compute_v = s.get("compute");
        let compute = match take_str(compute_v, "kind")? {
            "measured" => ComputeModel::Measured,
            "modeled" => ComputeModel::Modeled {
                flops_per_sec: take_f64(compute_v, "flops_per_sec")?,
            },
            other => return Err(format!("unknown compute model '{other}'")),
        };
        let speeds = match s.get("speeds") {
            Json::Arr(xs) => xs
                .iter()
                .enumerate()
                .map(|(i, x)| {
                    x.as_f64()
                        .ok_or_else(|| format!("sim.speeds[{i}]: expected a number"))
                })
                .collect::<Result<Vec<f64>, String>>()?,
            Json::Null => Vec::new(),
            _ => return Err("sim.speeds: expected an array".into()),
        };
        let straggler = match s.get("straggler") {
            Json::Null => None,
            st @ Json::Obj(_) => {
                let prob = take_f64(st, "prob")?;
                let slowdown = take_f64(st, "slowdown")?;
                let len = take_usize(st, "len")?;
                if !(0.0..=1.0).contains(&prob) {
                    return Err("straggler.prob must be in [0, 1]".into());
                }
                if slowdown < 1.0 || slowdown.is_nan() {
                    return Err("straggler.slowdown must be ≥ 1".into());
                }
                if len < 1 || len > u32::MAX as usize {
                    return Err("straggler.len must be in [1, u32::MAX]".into());
                }
                Some(StragglerConfig::new(
                    prob,
                    slowdown,
                    len as u32,
                    take_u64(st, "seed")?,
                ))
            }
            _ => return Err("sim.straggler: expected an object or null".into()),
        };
        let sim = SimSpec {
            m: take_usize(s, "m")?,
            seed: take_u64(s, "seed")?,
            cost,
            compute,
            node_threads: take_usize(s, "node_threads")?.max(1),
            speeds,
            weighted_partition: take_bool(s, "weighted_partition")?,
            straggler,
            trace: take_bool(s, "trace")?,
            // Lenient: absent in pre-events spec files ⇒ off.
            events: matches!(s.get("events"), Json::Bool(true)),
            // Lenient: absent in pre-overlap spec files ⇒ blocking.
            overlap: matches!(s.get("overlap"), Json::Bool(true)),
        };
        let st = v.get("stop");
        let stop = StopSpec {
            grad_tol: take_f64(st, "grad_tol")?,
            max_outer: take_usize(st, "max_outer")?,
            max_sim_seconds: match st.get("max_sim_seconds") {
                Json::Null => None,
                _ => Some(take_f64(st, "max_sim_seconds")?),
            },
            max_rounds: match st.get("max_rounds") {
                Json::Null => None,
                _ => Some(take_u64(st, "max_rounds")?),
            },
        };
        let spec = RunSpec { algo, loss, lambda: take_f64(v, "lambda")?, data, sim, stop };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json_str(text: &str) -> Result<RunSpec, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        RunSpec::from_json(&v)
    }
}

// ---------------------------------------------------------------------------
// CLI bridge — the spec-backed flag surface shared by `disco` and
// `disco-node`
// ---------------------------------------------------------------------------

/// Declare every spec-backed solver flag on a CLI schema. Defaults shown
/// in `--help` are the spec defaults; a flag only overrides the spec when
/// it is given explicitly (so `--spec run.json` plus a few overrides
/// composes as expected).
pub fn with_spec_flags(args: Args) -> Args {
    args.opt("spec", None, "load a RunSpec JSON file; explicit flags override its fields")
        .opt("dataset", Some("tiny"), "registered dataset name (see `disco datasets`)")
        .opt("scale", Some("1"), "down-scale factor for the dataset")
        .opt("store", None, "load the dataset out-of-core from this shard store (see `disco ingest`)")
        .opt("algo", Some("disco-f"), "disco-f | disco-s | disco | dane | cocoa+ | gd")
        .opt("loss", Some("logistic"), "logistic | quadratic | squared_hinge")
        .opt("lambda", None, "ℓ2 regularization (default: dataset registry value)")
        .opt("m", Some("4"), "number of simulated nodes")
        .opt("tau", Some("100"), "preconditioner sample count (paper §5.2; DiSCO variants)")
        .opt("mu", Some("0.01"), "preconditioner / DANE subproblem damping μ")
        .opt("pcg-beta", Some("0.05"), "PCG forcing factor: ε_k = β·‖∇f(w_k)‖ (DiSCO variants)")
        .opt("max-pcg", Some("500"), "PCG steps cap per outer iteration (DiSCO variants)")
        .opt("max-outer", Some("100"), "outer (Newton) iteration cap")
        .opt("grad-tol", Some("1e-8"), "stop when ‖∇f‖ ≤ this")
        .opt("max-sim-seconds", None, "stop once the simulated clock passes this budget")
        .opt("max-rounds", None, "stop once this many vector communication rounds were spent")
        .opt("hessian-fraction", Some("1.0"), "Fig. 5 Hessian subsampling fraction")
        .switch("balanced-partition", "DiSCO-F: balance feature shards by modeled row work")
        .opt("node-threads", Some("1"), "intra-node threads for the HVP kernels")
        .opt("local-epochs", Some("3"), "CoCoA+/DANE local solver epochs")
        .opt("dane-eta", Some("1.0"), "DANE gradient weight η")
        .opt("sag-inner-tol", Some("0.05"), "original DiSCO: SAG inner solve tolerance factor")
        .opt("sag-max-epochs", Some("30"), "original DiSCO: SAG epoch cap per solve")
        .opt("seed", Some("42"), "PRNG seed")
        .opt("net", Some("default"), "network cost model preset: default | zero | slow")
        .opt("collective", Some("binomial"), "collective pricing: flat | binomial | ring")
        .opt(
            "compute",
            Some("measured"),
            "clock model: measured | modeled | modeled:<rate> (modeled = bit-identical runs)",
        )
        .opt("speeds", None, "per-node relative speeds, comma-separated (e.g. 1,1,1,0.25)")
        .switch("weighted-partition", "size shards by node speed (heterogeneous fleets)")
        .opt("straggler", None, "seeded slowdown episodes: prob,slowdown,len,seed")
        .switch("trace", "record + print the per-node activity trace (Fig. 2)")
        .switch("overlap", "split-phase PCG: overlap HVP collectives with blocked compute")
        .opt(
            "events",
            None,
            "record the structured event stream and write it as JSONL to this path",
        )
}

fn parse_cost_preset(s: &str) -> Result<CostModel, String> {
    match s {
        "default" => Ok(CostModel::default()),
        "zero" => Ok(CostModel::zero()),
        "slow" => Ok(CostModel::slow()),
        other => Err(format!("unknown net model '{other}'")),
    }
}

fn parse_compute(s: &str) -> Result<ComputeModel, String> {
    match s {
        "measured" => Ok(ComputeModel::Measured),
        "modeled" => Ok(ComputeModel::modeled()),
        other => match other.strip_prefix("modeled:") {
            Some(rate) => {
                let r: f64 = rate
                    .parse()
                    .map_err(|_| format!("bad modeled rate '{rate}'"))?;
                if !(r.is_finite() && r > 0.0) {
                    return Err("modeled rate must be positive and finite".into());
                }
                Ok(ComputeModel::Modeled { flops_per_sec: r })
            }
            None => Err(format!("unknown compute model '{other}'")),
        },
    }
}

fn parse_straggler(s: &str) -> Result<StragglerConfig, String> {
    let parts: Vec<&str> = s.split(',').collect();
    if parts.len() != 4 {
        return Err("--straggler wants prob,slowdown,len,seed".into());
    }
    let prob: f64 = parts[0].parse().map_err(|_| "bad straggler prob")?;
    let slowdown: f64 = parts[1].parse().map_err(|_| "bad straggler slowdown")?;
    let len: u32 = parts[2].parse().map_err(|_| "bad straggler len")?;
    let seed: u64 = parts[3].parse().map_err(|_| "bad straggler seed")?;
    if !(0.0..=1.0).contains(&prob) || slowdown < 1.0 || slowdown.is_nan() || len < 1 {
        return Err("straggler: prob ∈ [0,1], slowdown ≥ 1, len ≥ 1".into());
    }
    Ok(StragglerConfig::new(prob, slowdown, len, seed))
}

/// Apply every *explicitly provided* flag onto `spec` (defaults never
/// override a loaded spec file). Knobs foreign to the selected algorithm
/// are ignored, mirroring the flat CLI they replace.
pub fn apply_args(spec: &mut RunSpec, args: &Args) -> Result<(), String> {
    let e = |err: crate::util::cli::CliError| err.to_string();
    // Algorithm/loss first: they decide which knob flags are meaningful.
    if args.provided("algo") {
        let name = args.req("algo").map_err(e)?;
        let kind = AlgoKind::parse(&name).ok_or_else(|| format!("bad --algo '{name}'"))?;
        if kind != spec.kind() {
            spec.algo = AlgoParams::for_kind(kind);
        }
    }
    if args.provided("loss") {
        let name = args.req("loss").map_err(e)?;
        spec.loss = LossKind::parse(&name).ok_or_else(|| format!("bad --loss '{name}'"))?;
    }
    if args.provided("lambda") {
        spec.lambda = args.get_f64("lambda").map_err(e)?;
    }
    if args.provided("dataset") {
        spec.data.name = args.req("dataset").map_err(e)?;
    }
    if args.provided("scale") {
        spec.data.scale = args.get_usize("scale").map_err(e)?.max(1);
    }
    if args.provided("store") {
        spec.data.store = Some(args.req("store").map_err(e)?);
        // The schema's `--dataset` default ("tiny") is not an assertion
        // about the store's content: the manifest name-check only applies
        // to an *explicitly* named dataset.
        if !args.provided("dataset") && spec.data.name == "tiny" {
            spec.data.name.clear();
        }
    }
    if let Some(p) = spec.algo.disco_mut() {
        if args.provided("tau") {
            p.tau = args.get_usize("tau").map_err(e)?;
        }
        if args.provided("mu") {
            p.mu = args.get_f64("mu").map_err(e)?;
        }
        if args.provided("pcg-beta") {
            p.pcg_beta = args.get_f64("pcg-beta").map_err(e)?;
        }
        if args.provided("max-pcg") {
            p.max_pcg = args.get_usize("max-pcg").map_err(e)?;
        }
        if args.provided("hessian-fraction") {
            p.hessian_fraction = args.get_f64("hessian-fraction").map_err(e)?;
        }
        if args.flag("balanced-partition") {
            p.balanced_partition = true;
        }
    }
    match &mut spec.algo {
        AlgoParams::DiscoOrig(_, sag) => {
            if args.provided("sag-inner-tol") {
                sag.inner_tol = args.get_f64("sag-inner-tol").map_err(e)?;
            }
            if args.provided("sag-max-epochs") {
                sag.max_epochs = args.get_usize("sag-max-epochs").map_err(e)?;
            }
        }
        AlgoParams::Dane(d) => {
            if args.provided("dane-eta") {
                d.eta = args.get_f64("dane-eta").map_err(e)?;
            }
            if args.provided("mu") {
                d.mu = args.get_f64("mu").map_err(e)?;
            }
            if args.provided("local-epochs") {
                d.local_epochs = args.get_usize("local-epochs").map_err(e)?;
            }
        }
        AlgoParams::CocoaPlus(cp) => {
            if args.provided("local-epochs") {
                cp.local_epochs = args.get_usize("local-epochs").map_err(e)?;
            }
        }
        _ => {}
    }
    if args.provided("m") {
        spec.sim.m = args.get_usize("m").map_err(e)?;
    }
    if args.provided("seed") {
        spec.sim.seed = args.get_u64("seed").map_err(e)?;
    }
    if args.provided("net") {
        let preset = parse_cost_preset(&args.req("net").map_err(e)?)?;
        // Keep an explicitly chosen collective algorithm (applied below).
        let algo = spec.sim.cost.algo;
        spec.sim.cost = CostModel { algo, ..preset };
    }
    if args.provided("collective") {
        let name = args.req("collective").map_err(e)?;
        spec.sim.cost.algo = CollectiveAlgo::parse(&name)
            .ok_or_else(|| format!("unknown collective algorithm '{name}'"))?;
    }
    if args.provided("compute") {
        spec.sim.compute = parse_compute(&args.req("compute").map_err(e)?)?;
    }
    if args.provided("node-threads") {
        spec.sim.node_threads = args.get_usize("node-threads").map_err(e)?.max(1);
    }
    if args.provided("speeds") {
        let raw = args.req("speeds").map_err(e)?;
        spec.sim.speeds = raw
            .split(',')
            .map(|t| t.trim().parse::<f64>().map_err(|_| format!("bad speed '{t}'")))
            .collect::<Result<Vec<f64>, String>>()?;
    }
    if args.flag("weighted-partition") {
        spec.sim.weighted_partition = true;
    }
    if args.provided("straggler") {
        spec.sim.straggler = Some(parse_straggler(&args.req("straggler").map_err(e)?)?);
    }
    if args.flag("trace") {
        spec.sim.trace = true;
    }
    if args.provided("events") {
        spec.sim.events = true;
    }
    if args.flag("overlap") {
        spec.sim.overlap = true;
    }
    if args.provided("grad-tol") {
        spec.stop.grad_tol = args.get_f64("grad-tol").map_err(e)?;
    }
    if args.provided("max-outer") {
        spec.stop.max_outer = args.get_usize("max-outer").map_err(e)?;
    }
    if args.provided("max-sim-seconds") {
        spec.stop.max_sim_seconds = Some(args.get_f64("max-sim-seconds").map_err(e)?);
    }
    if args.provided("max-rounds") {
        spec.stop.max_rounds = Some(args.get_u64("max-rounds").map_err(e)?);
    }
    Ok(())
}

/// Resolve the full spec from a CLI: `--spec file.json` (when given) as
/// the base, paper defaults otherwise (λ falling back to the dataset's
/// registry value), then explicit flags on top. Validates before
/// returning.
pub fn spec_from_args(args: &Args) -> Result<RunSpec, String> {
    let mut spec = if args.provided("spec") {
        let path = args.req("spec").map_err(|e| e.to_string())?;
        let text = std::fs::read_to_string(&path)
            .map_err(|err| format!("cannot read spec '{path}': {err}"))?;
        RunSpec::from_json_str(&text).map_err(|err| format!("bad spec '{path}': {err}"))?
    } else {
        let algo_name = args.get("algo").unwrap_or_else(|| "disco-f".into());
        let kind = AlgoKind::parse(&algo_name).ok_or_else(|| format!("bad --algo '{algo_name}'"))?;
        let loss_name = args.get("loss").unwrap_or_else(|| "logistic".into());
        let loss = LossKind::parse(&loss_name).ok_or_else(|| format!("bad --loss '{loss_name}'"))?;
        let dataset = args.get("dataset").unwrap_or_else(|| "tiny".into());
        let lambda = match args.get("lambda") {
            Some(l) => l.parse().map_err(|_| "bad --lambda")?,
            None => registry::spec(&dataset).map(|s| s.lambda).unwrap_or(1e-4),
        };
        RunSpec::new(kind, loss, lambda).with_data(&dataset, 1)
    };
    apply_args(&mut spec, args)?;
    spec.validate()?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256pp;

    fn sample_spec(kind: AlgoKind) -> RunSpec {
        let mut spec = RunSpec::new(kind, LossKind::Logistic, 1e-4).with_data("tiny", 8);
        spec.sim.compute = ComputeModel::modeled();
        spec
    }

    #[test]
    fn defaults_match_paper() {
        let spec = RunSpec::new(AlgoKind::DiscoF, LossKind::Logistic, 1e-4);
        let p = spec.algo.disco().unwrap();
        assert_eq!(p.tau, 100); // §5.2
        assert_eq!(p.mu, 1e-2); // §5.2
        assert_eq!(spec.sim.m, 4); // 4 EC2 instances
        assert_eq!(spec.stop.grad_tol, GRAD_TOL_DEFAULT);
        assert_eq!(p.hessian_fraction, 1.0);
    }

    #[test]
    fn json_round_trips_every_kind() {
        for &kind in AlgoKind::all() {
            let spec = sample_spec(kind);
            let text = spec.to_json_string();
            let back = RunSpec::from_json_str(&text).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            assert_eq!(spec, back, "{kind:?}");
        }
    }

    #[test]
    fn json_round_trips_non_finite_and_options() {
        let mut spec = sample_spec(AlgoKind::DiscoS);
        spec.sim.cost = CostModel::zero(); // β = ∞
        spec.sim.speeds = vec![1.0, 1.0, 1.0, 0.25];
        spec.sim.weighted_partition = true;
        spec.sim.straggler = Some(StragglerConfig::new(0.25, 4.0, 2, u64::MAX - 3));
        spec.stop.max_sim_seconds = Some(1.5);
        spec.stop.max_rounds = Some(123_456_789_012_345);
        spec.data.store = Some("/tmp/rcv1s.store".into());
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back.data.store.as_deref(), Some("/tmp/rcv1s.store"));
        assert_eq!(spec, back);
        assert_eq!(back.sim.cost.beta, f64::INFINITY);
        assert_eq!(back.sim.straggler.unwrap().seed, u64::MAX - 3);
    }

    /// Property: a randomized spec survives the JSON round trip bit-exactly
    /// (f64 knobs compare by bits via PartialEq on finite values; the
    /// generator draws awkward magnitudes on purpose).
    #[test]
    fn prop_json_round_trip_random_specs() {
        let mut rng = Xoshiro256pp::seed_from_u64(2024);
        for trial in 0..200 {
            let kind = AlgoKind::all()[rng.index(AlgoKind::all().len())];
            let loss = [LossKind::Logistic, LossKind::Quadratic, LossKind::SquaredHinge]
                [rng.index(3)];
            let mut spec = RunSpec::new(kind, loss, 10f64.powf(rng.uniform(-9.0, 0.0)));
            if let Some(p) = spec.algo.disco_mut() {
                p.tau = rng.index(500);
                p.mu = 10f64.powf(rng.uniform(-6.0, 0.0));
                p.pcg_beta = rng.next_f64();
                p.max_pcg = 1 + rng.index(1000);
                p.hessian_fraction = (rng.next_f64()).max(1e-3);
                p.balanced_partition = rng.next_f64() < 0.5;
            }
            spec.sim.m = 1 + rng.index(8);
            spec.sim.seed = rng.next_u64();
            spec.sim.cost.alpha = rng.next_f64() * 1e-3;
            spec.sim.cost.beta = if rng.next_f64() < 0.2 {
                f64::INFINITY
            } else {
                1.0 + rng.next_f64() * 1e9
            };
            spec.sim.cost.algo =
                CollectiveAlgo::all()[rng.index(CollectiveAlgo::all().len())];
            spec.sim.compute = if rng.next_f64() < 0.5 {
                ComputeModel::Measured
            } else {
                ComputeModel::Modeled { flops_per_sec: 1.0 + rng.next_f64() * 4e9 }
            };
            spec.sim.node_threads = 1 + rng.index(4);
            if rng.next_f64() < 0.5 {
                spec.sim.speeds = (0..spec.sim.m).map(|_| 0.1 + rng.next_f64()).collect();
                spec.sim.weighted_partition = rng.next_f64() < 0.5;
            }
            if rng.next_f64() < 0.3 {
                spec.sim.straggler = Some(StragglerConfig::new(
                    rng.next_f64(),
                    1.0 + rng.next_f64() * 7.0,
                    1 + rng.index(5) as u32,
                    rng.next_u64(),
                ));
            }
            spec.sim.trace = rng.next_f64() < 0.5;
            spec.sim.events = rng.next_f64() < 0.5;
            spec.sim.overlap = rng.next_f64() < 0.5;
            if rng.next_f64() < 0.3 {
                spec.data.store = Some(format!("stores/trial-{trial}"));
            }
            spec.stop.grad_tol = 10f64.powf(rng.uniform(-12.0, -3.0));
            spec.stop.max_outer = 1 + rng.index(500);
            if rng.next_f64() < 0.4 {
                spec.stop.max_sim_seconds = Some(rng.next_f64() * 100.0 + 1e-6);
            }
            if rng.next_f64() < 0.4 {
                spec.stop.max_rounds = Some(rng.next_u64() >> 12);
            }
            let text = spec.to_json_string();
            let back = RunSpec::from_json_str(&text)
                .unwrap_or_else(|err| panic!("trial {trial}: {err}\n{text}"));
            assert_eq!(spec, back, "trial {trial} diverged\n{text}");
        }
    }

    #[test]
    fn config_round_trip_preserves_relevant_knobs() {
        for &kind in AlgoKind::all() {
            let mut cfg = RunConfig::new(kind, LossKind::Quadratic, 3e-3);
            cfg.m = 5;
            cfg.tau = 17;
            cfg.pcg_beta = 0.125;
            cfg.dane_eta = 0.75;
            cfg.local_epochs = 9;
            cfg.sag_inner_tol = 0.01;
            cfg.seed = 31;
            cfg.trace = true;
            let spec = cfg.to_spec();
            assert_eq!(spec.kind(), kind);
            let back = spec.to_config();
            assert_eq!(back.m, 5);
            assert_eq!(back.seed, 31);
            assert_eq!(back.grad_tol, cfg.grad_tol);
            match kind {
                AlgoKind::DiscoF | AlgoKind::DiscoS | AlgoKind::DiscoOrig => {
                    assert_eq!(back.tau, 17);
                    assert_eq!(back.pcg_beta, 0.125);
                }
                AlgoKind::Dane => {
                    assert_eq!(back.dane_eta, 0.75);
                    assert_eq!(back.local_epochs, 9);
                }
                AlgoKind::CocoaPlus => assert_eq!(back.local_epochs, 9),
                AlgoKind::Gd => {}
            }
        }
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let mut spec = sample_spec(AlgoKind::DiscoF);
        spec.sim.speeds = vec![1.0, 2.0]; // m = 4
        assert!(spec.validate().is_err());
        let mut spec = sample_spec(AlgoKind::DiscoF);
        spec.algo.disco_mut().unwrap().hessian_fraction = 0.0;
        assert!(spec.validate().is_err());
        let mut spec = sample_spec(AlgoKind::DiscoF);
        spec.sim.m = 0;
        assert!(spec.validate().is_err());
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn repartition_flags_parse_and_validate() {
        let schema = RepartitionSpec::with_flags(Args::new("t", "t"));
        // Absent flags: disabled.
        let rp = RepartitionSpec::from_args(&schema.clone().parse(&argv(&[])).unwrap()).unwrap();
        assert_eq!(rp, RepartitionSpec::none());
        assert!(!rp.enabled());
        // Window + threshold + policy.
        let a = schema
            .clone()
            .parse(&argv(&[
                "--repartition-every",
                "3",
                "--repartition-threshold",
                "1.5",
                "--repartition-policy",
                "known",
            ]))
            .unwrap();
        let rp = RepartitionSpec::from_args(&a).unwrap();
        assert_eq!(rp.every, Some(3));
        assert_eq!(rp.threshold, 1.5);
        assert_eq!(rp.policy, RepartitionPolicy::Known);
        // 0 window = explicit off; bad threshold rejected.
        let a = schema
            .clone()
            .parse(&argv(&["--repartition-every", "0"]))
            .unwrap();
        assert!(!RepartitionSpec::from_args(&a).unwrap().enabled());
        let a = schema
            .clone()
            .parse(&argv(&["--repartition-threshold", "0.5"]))
            .unwrap();
        assert!(RepartitionSpec::from_args(&a).is_err());
        let a = schema
            .parse(&argv(&["--repartition-policy", "psychic"]))
            .unwrap();
        assert!(RepartitionSpec::from_args(&a).is_err());
    }

    #[test]
    fn cli_flags_override_spec() {
        let schema = with_spec_flags(Args::new("t", "t"));
        let argv: Vec<String> = [
            "--algo", "dane", "--dane-eta", "0.5", "--m", "3", "--compute", "modeled:1e9",
            "--max-rounds", "250", "--speeds", "1,1,0.5", "--weighted-partition", "--overlap",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = schema.parse(&argv).unwrap();
        let spec = spec_from_args(&args).unwrap();
        assert_eq!(spec.kind(), AlgoKind::Dane);
        match &spec.algo {
            AlgoParams::Dane(d) => assert_eq!(d.eta, 0.5),
            other => panic!("{other:?}"),
        }
        assert_eq!(spec.sim.m, 3);
        assert_eq!(spec.sim.compute, ComputeModel::Modeled { flops_per_sec: 1e9 });
        assert_eq!(spec.stop.max_rounds, Some(250));
        assert_eq!(spec.sim.speeds, vec![1.0, 1.0, 0.5]);
        assert!(spec.sim.weighted_partition);
        assert!(spec.sim.overlap);
        // Defaults that were not provided stay at spec defaults.
        assert_eq!(spec.stop.max_outer, 100);
        assert_eq!(spec.stop.grad_tol, GRAD_TOL_DEFAULT);
    }
}
