//! Adaptive mid-run re-partitioning — load balancing from **measured**
//! speeds.
//!
//! The paper's load-balancing story (Ma & Takáč 2016; and the companion
//! partitioning study, Ma & Takáč 2015) sizes shards by *known* relative
//! node speeds before the run starts. Real fleets don't announce their
//! speeds: they demonstrate them. The [`Repartitioner`] closes that loop
//! on top of the step-wise [`Session`] driver:
//!
//! 1. **Observe** — over a window of `every` outer iterations it
//!    accumulates each rank's busy (compute) seconds from the context's
//!    always-on idle accounting
//!    ([`Collectives::compute_seconds`](crate::net::Collectives)), *minus*
//!    the shard-independent serial fraction
//!    ([`Collectives::serial_seconds`](crate::net::Collectives) — rank 0's
//!    master-side PCG vector algebra in DiSCO-S/orig does not shrink with
//!    its shard, so counting it would misread "doing serial work" as
//!    "slow node" and starve the master of data), and gathers the
//!    per-rank `(busy, shard work)` table in one *free* metrics round, so
//!    every rank sees identical data.
//! 2. **Estimate** — effective speed of rank `j` ∝ `work_j / busy_j`:
//!    the work units are exactly what the cut policy balances (sample
//!    counts for the sample-partitioned algorithms, `nnz + overhead·rows`
//!    for DiSCO-F), so the ratio is a direct quota weight.
//! 3. **Trigger** — re-cut only when the windowed busy imbalance
//!    `max/min` reaches `threshold`; a balanced fleet never pays the
//!    re-shard cost.
//! 4. **Re-cut & resume** — the session stops at the outer-iteration
//!    boundary it is already on, re-cuts via the *same* weighted policies
//!    the up-front heterogeneity knobs use
//!    ([`weighted_ranges`] / [`Partition::feature_cost_cuts`]), re-shards
//!    the cut-axis state through the handoff codec (one priced AllGather
//!    — see [`Session::repartition`]) and resumes.
//!
//! Everything the decision depends on is either reduced (the probe
//! table) or a pure function of the spec, so all ranks take the same
//! branch — SPMD-safe on the thread cluster and on a real TCP fleet
//! alike. Under [`ComputeModel::Modeled`](crate::net::ComputeModel) the
//! measured busy seconds are themselves deterministic, so an adaptive
//! run is **bit-identical across reruns and across transports**
//! (test- and CI-enforced via the `fig2h-adaptive` double-run diff).
//! With the trigger disabled (`every = None`) the driver adds zero
//! communication and zero branching: the run is bit-identical to a plain
//! [`Session`] run.

use crate::algorithms::common::{default_cuts, feature_row_overhead};
use crate::algorithms::session::Session;
use crate::algorithms::spec::{RepartitionPolicy, RepartitionSpec, RunSpec};
use crate::data::{weighted_ranges, Dataset, Partition, PartitionKind};
use crate::net::Collectives;
use crate::obs::{EventKind, Phase};

/// Per-rank adaptive load-balancing driver layered on [`Session`]; see
/// the module docs. Construct once per run, call
/// [`Repartitioner::after_step`] after every `Running` step.
pub struct Repartitioner {
    rp: RepartitionSpec,
    /// The current cut table — identical on every rank by construction
    /// (initial cuts and every re-cut are pure functions of reduced
    /// data), so re-cut idempotence needs no agreement traffic. Derived
    /// lazily at the first trigger (empty until then): `Session::setup`
    /// already computed the identical default table, and re-deriving it
    /// up front would double the O(nnz) row-work scan on every adaptive
    /// run — including the balanced fleets that never re-cut.
    ranges: Vec<(usize, usize)>,
    /// This rank's busy-seconds mark at the start of the current window.
    window_busy_mark: f64,
    /// Serial (shard-independent) busy-seconds mark at the window start:
    /// the window's serial delta is excluded from the speed probe.
    window_serial_mark: f64,
    steps_in_window: usize,
    recuts: usize,
}

impl Repartitioner {
    pub fn new<C: Collectives>(
        ctx: &C,
        _ds: &Dataset,
        _spec: &RunSpec,
        rp: RepartitionSpec,
    ) -> Repartitioner {
        Repartitioner {
            rp,
            ranges: Vec::new(),
            window_busy_mark: ctx.compute_seconds(),
            window_serial_mark: ctx.serial_seconds(),
            steps_in_window: 0,
            recuts: 0,
        }
    }

    /// Mid-run re-cuts performed so far (identical on every rank).
    pub fn recuts(&self) -> usize {
        self.recuts
    }

    /// The cut table currently in force (empty while disabled or until
    /// the first trigger evaluated one — after a re-cut it is always
    /// populated).
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Adopt the cut table a resumed checkpoint recorded — the baseline
    /// for the re-cut idempotence check. No-op while the trigger is
    /// disabled (the table is unused then).
    pub fn set_ranges(&mut self, ranges: Vec<(usize, usize)>) {
        if self.rp.enabled() {
            self.ranges = ranges;
        }
    }

    /// Observe one completed outer iteration; at window boundaries,
    /// measure, and re-cut when the trigger fires. Returns whether a
    /// re-cut happened. SPMD: every rank calls this after every
    /// `Running` step; all ranks take identical branches.
    pub fn after_step<C: Collectives>(
        &mut self,
        ctx: &mut C,
        session: &mut Session<C>,
        ds: &Dataset,
        spec: &RunSpec,
    ) -> Result<bool, String> {
        let Some(every) = self.rp.every else {
            return Ok(false);
        };
        self.steps_in_window += 1;
        if self.steps_in_window < every {
            return Ok(false);
        }
        self.steps_in_window = 0;

        // One free metrics round gathers the per-rank (busy, work)
        // table: each slot has exactly one contributor, so the reduced
        // vector is the full table — identical on every rank.
        let m = ctx.world();
        let rank = ctx.rank();
        let mut probe = vec![0.0; 2 * m];
        // Shard-proportional busy only: the serial delta is work whose
        // cost would not move if this rank's shard changed.
        probe[rank] = (ctx.compute_seconds() - self.window_busy_mark)
            - (ctx.serial_seconds() - self.window_serial_mark);
        probe[m + rank] = session.shard_work();
        ctx.metric_reduce_all(&mut probe);
        let (busy, work) = probe.split_at(m);

        let new_ranges = self.decide(busy, work, ds, spec);
        let did = match new_ranges {
            Some(ranges) => {
                if ctx.obs_enabled() {
                    ctx.obs_emit(EventKind::SpanBegin {
                        phase: Phase::Handoff,
                        label: format!("recut {}", self.recuts + 1),
                    });
                }
                session.repartition(ctx, ds, spec, &ranges)?;
                if ctx.obs_enabled() {
                    ctx.obs_emit(EventKind::SpanEnd {
                        phase: Phase::Handoff,
                        label: format!("recut {}", self.recuts + 1),
                    });
                }
                self.ranges = ranges;
                self.recuts += 1;
                true
            }
            None => false,
        };
        // Fresh window either way — and never attribute the re-cut's own
        // setup compute to the next observation window.
        self.window_busy_mark = ctx.compute_seconds();
        self.window_serial_mark = ctx.serial_seconds();
        Ok(did)
    }

    /// The trigger + estimator (pure function of the reduced probe table
    /// and the spec, so every rank decides identically). `None` = keep
    /// the current cut.
    fn decide(
        &mut self,
        busy: &[f64],
        work: &[f64],
        ds: &Dataset,
        spec: &RunSpec,
    ) -> Option<Vec<(usize, usize)>> {
        let bmax = busy.iter().cloned().fold(0.0, f64::max);
        let bmin = busy.iter().cloned().fold(f64::INFINITY, f64::min);
        // An unmeasurable window (a rank that did no costed compute, or a
        // non-finite reading) cannot support a speed estimate.
        if bmin <= 0.0 || !bmin.is_finite() || !bmax.is_finite() {
            return None;
        }
        if bmax / bmin < self.rp.threshold {
            return None;
        }
        let weights: Vec<f64> = match self.rp.policy {
            // Effective speed ∝ demonstrated throughput: shard work per
            // busy second.
            RepartitionPolicy::Measured => {
                busy.iter().zip(work.iter()).map(|(b, w)| w / b).collect()
            }
            RepartitionPolicy::Known => {
                if spec.sim.speeds.len() == busy.len() {
                    spec.sim.speeds.clone()
                } else {
                    return None; // no configured speeds to re-cut from
                }
            }
        };
        if !weights.iter().all(|w| w.is_finite() && *w > 0.0) {
            return None;
        }
        // Lazily derive the baseline the first time a trigger fires (the
        // session computed — and shards by — the identical table).
        if self.ranges.is_empty() {
            self.ranges = default_cuts(ds, spec);
        }
        let ranges = recut(ds, spec, &weights);
        if ranges == self.ranges {
            None
        } else {
            Some(ranges)
        }
    }
}

/// Re-cut `spec`'s partition axis with explicit weights, via the same
/// weighted policies the up-front heterogeneity knobs use:
/// [`Partition::feature_cost_cuts`] (work-balanced, speed-weighted) on
/// the feature axis, [`weighted_ranges`] on the sample axis.
pub fn recut(ds: &Dataset, spec: &RunSpec, weights: &[f64]) -> Vec<(usize, usize)> {
    match spec.kind().cut_axis() {
        PartitionKind::Features => {
            let p = spec
                .algo
                .disco()
                .expect("feature-partitioned algorithms carry DiscoParams");
            Partition::feature_cost_cuts(ds, weights, feature_row_overhead(p))
        }
        PartitionKind::Samples => weighted_ranges(ds.nsamples(), weights),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, RunSpec};
    use crate::data::SyntheticConfig;
    use crate::loss::LossKind;

    #[test]
    fn recut_uses_the_axis_appropriate_policy() {
        let ds = SyntheticConfig::new("t", 60, 30).density(0.2).seed(3).generate();
        let mut spec = RunSpec::new(AlgoKind::DiscoF, LossKind::Logistic, 1e-2);
        spec.sim.m = 3;
        let f = recut(&ds, &spec, &[1.0, 1.0, 0.5]);
        assert_eq!(f.len(), 3);
        assert_eq!(f.last().unwrap().1, ds.dim(), "feature axis");
        let spec = RunSpec::new(AlgoKind::Dane, LossKind::Logistic, 1e-2).with_m(3);
        let s = recut(&ds, &spec, &[1.0, 1.0, 0.5]);
        assert_eq!(s.last().unwrap().1, ds.nsamples(), "sample axis");
        // The straggler's shard shrinks on both axes.
        assert!(s[2].1 - s[2].0 < s[0].1 - s[0].0);
    }
}
