//! **DANE** (Distributed Approximate Newton, Shamir et al. 2013) — baseline
//! per the paper's §1.1 item 3 and §5.2.
//!
//! Each iteration: one ReduceAll to form the global gradient, then every
//! node solves the local subproblem (paper Eq. (1))
//!
//! ```text
//! w_j = argmin_w  f_j(w) − (∇f_j(w_k) − η∇f(w_k))ᵀ w + (μ/2)‖w − w_k‖²
//! ```
//!
//! with SAG (as in the paper's experiments: "we apply SAG to solve …
//! subproblem (1)"), followed by a second ReduceAll to average the local
//! solutions — two ℝᵈ vector rounds per iteration.
//!
//! Step-wise [`AlgorithmNode`]: the per-rank SAG stream is part of the
//! solver state (it advances every outer iteration), so checkpoints
//! serialize it and a resumed run draws the exact same sample sequence.

use crate::algorithms::algorithm::{Algorithm, AlgorithmNode, Handoff, StepReport};
use crate::algorithms::common::{decode_records, encode_records, put_bool, put_vec, read_bool};
use crate::algorithms::common::{read_vec_into, resolve_cuts, Recorder};
use crate::algorithms::spec::{DaneParams, RunSpec};
use crate::algorithms::{AlgoKind, NodeOutput};
use crate::data::{Dataset, Partition};
use crate::linalg::{ops, DataMatrix};
use crate::loss::Loss;
use crate::net::Collectives;
use crate::solvers::sag::SagSolver;
use crate::util::bytes::{put_u64, ByteReader};
use crate::util::prng::Xoshiro256pp;

/// The DANE baseline (factory for per-rank `DaneNode` state).
pub struct Dane;

impl<C: Collectives> Algorithm<C> for Dane {
    fn kind(&self) -> AlgoKind {
        AlgoKind::Dane
    }

    fn setup(
        &self,
        ctx: &mut C,
        ds: &Dataset,
        spec: &RunSpec,
        ranges: Option<&[(usize, usize)]>,
    ) -> Box<dyn AlgorithmNode<C>> {
        Box::new(DaneNode::new(ctx.rank(), ds, spec, ranges))
    }
}

struct DaneNode {
    // -- problem data / derived --
    x: DataMatrix,
    y: Vec<f64>,
    loss: Box<dyn Loss>,
    p: DaneParams,
    lambda: f64,
    m: usize,
    grad_tol: f64,
    n: usize,
    n_local: usize,
    nnz: f64,
    inv_nl: f64,
    /// SAG step-size bound: max per-sample curvature of the subproblem.
    lmax: f64,
    /// Sample-share weight p_j = n_j·m/n on weighted partitions (1.0 on
    /// uniform ones — the seed arithmetic bit-for-bit).
    pj: f64,
    /// Global sample range of this rank's shard (the cut axis).
    range: (usize, usize),
    // -- evolving solver state (serialized) --
    w: Vec<f64>,
    rng: Xoshiro256pp,
    recorder: Recorder,
    converged: bool,
    // -- scratch --
    z: Vec<f64>,
    g_scal: Vec<f64>,
    grad_local: Vec<f64>,
}

impl DaneNode {
    fn new(
        rank: usize,
        ds: &Dataset,
        spec: &RunSpec,
        ranges: Option<&[(usize, usize)]>,
    ) -> DaneNode {
        let p = match &spec.algo {
            crate::algorithms::AlgoParams::Dane(p) => *p,
            other => panic!("DANE spec carries {:?}", other.kind()),
        };
        let uniform_cut = ranges.is_none() && spec.sim.partition_speeds().is_none();
        let cuts = resolve_cuts(ds, spec, ranges);
        let range = cuts[rank];
        let shard = Partition::sample_shard(ds, rank, range);
        let x = shard.x; // d × n_j
        let y = shard.y;
        let n = ds.nsamples();
        let d = x.nrows();
        let n_local = x.ncols();
        let loss = spec.loss.make();
        let rng = Xoshiro256pp::seed_from_u64(spec.sim.seed.wrapping_add(rank as u64 * 7919));

        // SAG step-size bound: max per-sample curvature of the subproblem.
        let lmax = (0..n_local)
            .map(|j| loss.smoothness() * x.col_norm_sq(j))
            .fold(0.0, f64::max);

        // Global gradient = (1/m) Σ_j p_j ∇f_j (each f_j carries λw).
        // On a weighted partition — speed knobs up front, or an adaptive
        // re-cut handing in external ranges — the shards are deliberately
        // unequal and the classic unweighted average would silently
        // overweight the small shards' samples; the sample-share weight
        // p_j = n_j·m/n makes Σ p_j ∇f_j / m exactly ∇f. The uniform cut
        // keeps p_j = 1 (the seed arithmetic, bit-for-bit — including
        // the ±1-sample shards of a non-divisible n).
        let pj = if uniform_cut {
            1.0
        } else {
            n_local as f64 * spec.sim.m as f64 / n as f64
        };

        DaneNode {
            y,
            loss,
            p,
            lambda: spec.lambda,
            m: spec.sim.m,
            grad_tol: spec.stop.grad_tol,
            n,
            n_local,
            nnz: x.nnz() as f64,
            inv_nl: 1.0 / n_local as f64,
            lmax,
            pj,
            range,
            w: vec![0.0; d],
            rng,
            recorder: Recorder::new(rank),
            converged: false,
            z: vec![0.0; n_local],
            g_scal: vec![0.0; n_local],
            grad_local: vec![0.0; d],
            x,
        }
    }
}

impl<C: Collectives> AlgorithmNode<C> for DaneNode {
    fn kind(&self) -> AlgoKind {
        AlgoKind::Dane
    }

    fn step(&mut self, ctx: &mut C, outer: usize) -> StepReport {
        let (n, n_local, nnz, inv_nl, lmax, pj, lambda, m, grad_tol) = (
            self.n,
            self.n_local,
            self.nnz,
            self.inv_nl,
            self.lmax,
            self.pj,
            self.lambda,
            self.m,
            self.grad_tol,
        );
        let p = self.p;
        let DaneNode {
            x,
            y,
            loss,
            w,
            rng,
            recorder,
            converged,
            z,
            g_scal,
            grad_local,
            ..
        } = self;
        let x: &DataMatrix = x;
        let y: &[f64] = y;
        let loss: &dyn Loss = loss.as_ref();
        let d = w.len();

        // ---- local gradient of f_j at w_k (includes λw: f_j has its own
        // regularizer, Eq. (4)) and the global gradient (ReduceAll) ----
        let data_f = ctx.compute_costed("gradient", || {
            x.at_mul_into(w, z);
            for i in 0..n_local {
                g_scal[i] = loss.deriv(z[i], y[i]);
            }
            x.a_mul_into(g_scal, grad_local);
            ops::scale(inv_nl, grad_local);
            ops::axpy(lambda, w, grad_local);
            let f: f64 = z
                .iter()
                .zip(y.iter())
                .map(|(zi, yi)| loss.value(*zi, *yi))
                .sum();
            (f / n as f64, 4.0 * nnz + 2.0 * n_local as f64 + 3.0 * d as f64)
        });
        let mut grad = grad_local.clone();
        if pj != 1.0 {
            ops::scale(pj, &mut grad);
        }
        ctx.reduce_all(&mut grad);
        ops::scale(1.0 / m as f64, &mut grad);

        let grad_norm = ops::norm2(&grad);
        let mut fv = vec![data_f];
        ctx.metric_reduce_all(&mut fv);
        let fval = fv[0] + 0.5 * lambda * ops::norm2_sq(w);

        let record = recorder.push(ctx, outer, grad_norm, fval, 0);
        if grad_norm <= grad_tol {
            *converged = true;
            return StepReport { record, converged: true };
        }

        // ---- local subproblem via SAG ----
        // ∇(sub) = ∇f_j(w) − ∇f_j(w_k) + η∇f(w_k) + μ(w − w_k)
        //        = [data(w) + λw] + linear + μw, with
        // linear = −∇f_j(w_k) + η∇f(w_k) − μ w_k.
        let mut linear = vec![0.0; d];
        for i in 0..d {
            linear[i] = -grad_local[i] + p.eta * grad[i] - p.mu * w[i];
        }
        let w_new = ctx.compute_costed("local_solve", || {
            let solver = SagSolver {
                x,
                kappa: lambda + p.mu,
                linear: &linear,
                lmax,
            };
            let w_new = solver.run(|j, zj| loss.deriv(zj, y[j]), w, p.local_epochs, rng);
            // Per epoch: one sweep of the shard's nonzeros plus an O(d)
            // dense update per visited sample.
            let flops = p.local_epochs as f64 * (6.0 * nnz + 3.0 * (n_local * d) as f64);
            (w_new, flops)
        });

        // ---- average the local solutions (second ReduceAll); same
        // sample-share weighting as the gradient so unequal shards
        // contribute proportionally to the data they saw ----
        let mut wsum = w_new;
        if pj != 1.0 {
            ops::scale(pj, &mut wsum);
        }
        ctx.reduce_all(&mut wsum);
        for (wi, si) in w.iter_mut().zip(wsum.iter()) {
            *wi = si / m as f64;
        }

        StepReport { record, converged: false }
    }

    fn save_state(&self, buf: &mut Vec<u8>) {
        put_vec(buf, &self.w);
        for word in self.rng.state() {
            put_u64(buf, word);
        }
        put_bool(buf, self.converged);
        encode_records(buf, &self.recorder.records);
    }

    fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<(), String> {
        read_vec_into(r, &mut self.w)?;
        let state = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        self.rng = Xoshiro256pp::from_state(state);
        self.converged = read_bool(r)?;
        self.recorder.records = decode_records(r)?;
        Ok(())
    }

    fn finish(self: Box<Self>) -> NodeOutput {
        let me = *self;
        let primary = me.recorder.is_primary();
        NodeOutput {
            records: me.recorder.records,
            // Every rank holds the same averaged iterate; rank 0 reports
            // it.
            w_part: if primary { me.w } else { Vec::new() },
            ops: Default::default(),
            converged: me.converged,
        }
    }

    fn shard_range(&self) -> (usize, usize) {
        self.range
    }

    fn shard_work(&self) -> f64 {
        self.n_local as f64
    }

    fn export_handoff(&mut self) -> Handoff {
        // Iterate replicated, SAG stream per-rank: nothing crosses rank
        // boundaries on a re-cut (lmax and p_j are derived, rebuilt by
        // setup from the new shard), so the rank-local payload is exactly
        // the checkpoint codec — one serializer to keep in sync.
        let mut bytes = Vec::new();
        <DaneNode as AlgorithmNode<C>>::save_state(self, &mut bytes);
        Handoff { cut_axis: Vec::new(), bytes }
    }

    fn snapshot_handoff(&self) -> Handoff {
        let mut bytes = Vec::new();
        <DaneNode as AlgorithmNode<C>>::save_state(self, &mut bytes);
        Handoff { cut_axis: Vec::new(), bytes }
    }

    fn import_handoff(&mut self, _cut_axis: &[f64], bytes: &[u8]) -> Result<(), String> {
        let mut r = ByteReader::new(bytes);
        <DaneNode as AlgorithmNode<C>>::restore_state(self, &mut r)?;
        r.finish()
    }
}
