//! **DANE** (Distributed Approximate Newton, Shamir et al. 2013) — baseline
//! per the paper's §1.1 item 3 and §5.2.
//!
//! Each iteration: one ReduceAll to form the global gradient, then every
//! node solves the local subproblem (paper Eq. (1))
//!
//! ```text
//! w_j = argmin_w  f_j(w) − (∇f_j(w_k) − η∇f(w_k))ᵀ w + (μ/2)‖w − w_k‖²
//! ```
//!
//! with SAG (as in the paper's experiments: "we apply SAG to solve …
//! subproblem (1)"), followed by a second ReduceAll to average the local
//! solutions — two ℝᵈ vector rounds per iteration.

use crate::algorithms::common::{sample_partition, Recorder};
use crate::algorithms::{assemble, NodeOutput, RunConfig, RunResult};
use crate::data::{Dataset, Partition};
use crate::linalg::ops;
use crate::loss::Loss;
use crate::net::Collectives;
use crate::solvers::sag::SagSolver;
use crate::util::prng::Xoshiro256pp;

pub fn run(ds: &Dataset, cfg: &RunConfig) -> RunResult {
    let partition = sample_partition(ds, cfg);
    let loss = cfg.loss.make();
    let n = ds.nsamples();

    let cluster = cfg.cluster();
    let run = cluster.run(|ctx| node_main(ctx, &partition, loss.as_ref(), cfg, n));
    assemble(cfg.algo, run)
}

/// Per-rank entry over any collective backend (multi-process runs).
pub(crate) fn node_run<C: Collectives>(ctx: &mut C, ds: &Dataset, cfg: &RunConfig) -> NodeOutput {
    let partition = sample_partition(ds, cfg);
    let loss = cfg.loss.make();
    node_main(ctx, &partition, loss.as_ref(), cfg, ds.nsamples())
}

fn node_main<C: Collectives>(
    ctx: &mut C,
    partition: &Partition,
    loss: &dyn Loss,
    cfg: &RunConfig,
    n: usize,
) -> NodeOutput {
    let rank = ctx.rank();
    let shard = &partition.shards[rank];
    let x = &shard.x; // d × n_j
    let y = &shard.y;
    let d = x.nrows();
    let n_local = x.ncols();
    let nnz = x.nnz() as f64;
    let inv_nl = 1.0 / n_local as f64;

    let mut w = vec![0.0; d];
    let mut recorder = Recorder::new(rank);
    let mut converged = false;
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed.wrapping_add(rank as u64 * 7919));

    // SAG step-size bound: max per-sample curvature of the subproblem.
    let lmax = (0..n_local)
        .map(|j| loss.smoothness() * x.col_norm_sq(j))
        .fold(0.0, f64::max);

    let mut z = vec![0.0; n_local];
    let mut g_scal = vec![0.0; n_local];
    let mut grad_local = vec![0.0; d];

    for outer in 0..cfg.max_outer {
        // ---- local gradient of f_j at w_k (includes λw: f_j has its own
        // regularizer, Eq. (4)) and the global gradient (ReduceAll) ----
        let data_f = ctx.compute_costed("gradient", || {
            x.at_mul_into(&w, &mut z);
            for i in 0..n_local {
                g_scal[i] = loss.deriv(z[i], y[i]);
            }
            x.a_mul_into(&g_scal, &mut grad_local);
            ops::scale(inv_nl, &mut grad_local);
            ops::axpy(cfg.lambda, &w, &mut grad_local);
            let f: f64 = z
                .iter()
                .zip(y.iter())
                .map(|(zi, yi)| loss.value(*zi, *yi))
                .sum();
            (f / n as f64, 4.0 * nnz + 2.0 * n_local as f64 + 3.0 * d as f64)
        });
        // Global gradient = (1/m) Σ_j p_j ∇f_j (each f_j carries λw).
        // On a speed-weighted partition the shards are deliberately
        // unequal and the classic unweighted average would silently
        // overweight the small shards' samples; the sample-share weight
        // p_j = n_j·m/n makes Σ p_j ∇f_j / m exactly ∇f. Uniform
        // partitions keep p_j = 1 (the seed arithmetic, bit-for-bit —
        // including the ±1-sample shards of a non-divisible n).
        let pj = if cfg.partition_speeds().is_some() {
            n_local as f64 * cfg.m as f64 / n as f64
        } else {
            1.0
        };
        let mut grad = grad_local.clone();
        if pj != 1.0 {
            ops::scale(pj, &mut grad);
        }
        ctx.reduce_all(&mut grad);
        ops::scale(1.0 / cfg.m as f64, &mut grad);

        let grad_norm = ops::norm2(&grad);
        let mut fv = vec![data_f];
        ctx.metric_reduce_all(&mut fv);
        let fval = fv[0] + 0.5 * cfg.lambda * ops::norm2_sq(&w);

        recorder.push(ctx, outer, grad_norm, fval, 0);
        if grad_norm <= cfg.grad_tol {
            converged = true;
            break;
        }

        // ---- local subproblem via SAG ----
        // ∇(sub) = ∇f_j(w) − ∇f_j(w_k) + η∇f(w_k) + μ(w − w_k)
        //        = [data(w) + λw] + linear + μw, with
        // linear = −∇f_j(w_k) + η∇f(w_k) − μ w_k.
        let mut linear = vec![0.0; d];
        for i in 0..d {
            linear[i] = -grad_local[i] + cfg.dane_eta * grad[i] - cfg.mu * w[i];
        }
        let w_new = ctx.compute_costed("local_solve", || {
            let solver = SagSolver {
                x,
                kappa: cfg.lambda + cfg.mu,
                linear: &linear,
                lmax,
            };
            let w_new = solver.run(|j, zj| loss.deriv(zj, y[j]), &w, cfg.local_epochs, &mut rng);
            // Per epoch: one sweep of the shard's nonzeros plus an O(d)
            // dense update per visited sample.
            let flops = cfg.local_epochs as f64 * (6.0 * nnz + 3.0 * (n_local * d) as f64);
            (w_new, flops)
        });

        // ---- average the local solutions (second ReduceAll); same
        // sample-share weighting as the gradient so unequal shards
        // contribute proportionally to the data they saw ----
        let mut wsum = w_new;
        if pj != 1.0 {
            ops::scale(pj, &mut wsum);
        }
        ctx.reduce_all(&mut wsum);
        for (wi, si) in w.iter_mut().zip(wsum.iter()) {
            *wi = si / cfg.m as f64;
        }
    }

    NodeOutput {
        records: recorder.records,
        // Every rank holds the same averaged iterate; rank 0 reports it.
        w_part: if rank == 0 { w } else { Vec::new() },
        ops: Default::default(),
        converged,
    }
}
