//! Resumable step-wise run driver.
//!
//! A [`Session`] owns the outer loop that the legacy run-to-completion
//! entrypoints hid: each [`Session::step`] executes exactly one outer
//! iteration of the configured [`Algorithm`](crate::algorithms::Algorithm)
//! through the object-safe [`AlgorithmNode`] surface, then evaluates the
//! composable [`StopSpec`] policy (gradient tolerance ∧ outer cap ∧
//! simulated-time budget ∧ communication-round budget). Between steps the
//! caller can observe [`StepReport`]s, feed dashboards, or
//! [`Session::checkpoint`] the run.
//!
//! Sessions are **per-rank** objects, like everything else in the SPMD
//! runtime: every rank drives its own session in lockstep, and all stop
//! decisions derive from reduced scalars (or, for the simulated-time
//! budget, one *free* metrics round per iteration) so ranks can never
//! disagree.
//!
//! ## Checkpoint format
//!
//! [`Session::checkpoint`] serializes, per rank, through the
//! little-endian codec of [`crate::util::bytes`]:
//!
//! ```text
//! "DSK1" | algo u8 | rank u32 | world u32 | outer u64
//! global-ledger flag u8 [CommStats]        (shm blackboard snapshot)
//! clock f64 | CommStats mirror | straggler flag u8 [rng 4×u64, left u32]
//! trace: nseg u32, Segment*                (empty when tracing is off)
//! algorithm payload                        (AlgorithmNode::save_state)
//! ```
//!
//! Everything *derivable* — shards, CSR mirrors, Woodbury factorizations —
//! is rebuilt on restore without touching the simulated clock, so under
//! [`ComputeModel::Modeled`](crate::net::ComputeModel) a resumed run is
//! **bit-identical** to an uninterrupted one: same records, same
//! `sim_seconds`, same traces, same [`CommStats`] (the shm global ledger
//! is re-seeded so its f64 accumulation *continues* rather than restarts
//! — see [`crate::net::Cluster::with_initial_stats`]). Restore a
//! checkpoint only on the transport kind that wrote it.

use crate::algorithms::algorithm::{AlgorithmNode, StepReport};
use crate::algorithms::spec::{RunSpec, StopSpec};
use crate::algorithms::{assemble, AlgoKind, NodeOutput, RunResult};
use crate::data::Dataset;
use crate::net::{Collectives, CommStats, CtxState, Segment};
use crate::util::bytes::{put_f64, put_u32, put_u64, put_u8, ByteReader};

const CKPT_MAGIC: &[u8; 4] = b"DSK1";

/// Why a session stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// ‖∇f‖ reached `stop.grad_tol`.
    Converged,
    /// `stop.max_outer` iterations ran.
    OuterCap,
    /// The simulated clock passed `stop.max_sim_seconds`.
    SimTimeBudget,
    /// `stop.max_rounds` vector rounds were spent.
    RoundBudget,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::Converged => "converged",
            StopReason::OuterCap => "outer-cap",
            StopReason::SimTimeBudget => "sim-time-budget",
            StopReason::RoundBudget => "round-budget",
        }
    }
}

/// Outcome of one [`Session::step`] call.
#[derive(Clone, Debug)]
pub enum SessionStatus {
    /// One outer iteration ran; the run continues.
    Running(StepReport),
    /// The run is over. When the final iteration executed during this call
    /// its report is attached; `None` means a pre-step policy (the outer
    /// cap) fired or the session was already stopped.
    Stopped(StopReason, Option<StepReport>),
}

/// Per-rank step-wise driver. See the module docs; construct with
/// [`Session::new`], advance with [`Session::step`], drain with
/// [`Session::finish`].
///
/// # Example
///
/// ```
/// use disco::algorithms::{run_spec, AlgoKind, RunSpec};
/// use disco::data::SyntheticConfig;
/// use disco::loss::LossKind;
///
/// let ds = SyntheticConfig::new("doc", 64, 24).density(0.3).seed(2).generate();
/// let mut spec = RunSpec::new(AlgoKind::Gd, LossKind::Quadratic, 1e-2);
/// spec.stop.max_outer = 5;
/// spec.stop.grad_tol = 0.0; // run all 5 iterations
/// let res = run_spec(&ds, &spec);
/// assert_eq!(res.records.len(), 5);
/// ```
pub struct Session<C: Collectives> {
    node: Box<dyn AlgorithmNode<C>>,
    stop: StopSpec,
    outer: usize,
    stopped: Option<StopReason>,
}

impl<C: Collectives> Session<C> {
    /// Build this rank's solver state for `spec` (runs
    /// [`Algorithm::setup`](crate::algorithms::Algorithm::setup), which
    /// costs the pre-loop compute through `ctx`).
    pub fn new(ctx: &mut C, ds: &Dataset, spec: &RunSpec) -> Session<C> {
        let algorithm = spec.algo.algorithm::<C>();
        let node = algorithm.setup(ctx, ds, spec);
        Session {
            node,
            stop: spec.stop.clone(),
            outer: 0,
            stopped: None,
        }
    }

    /// Outer iterations completed so far (equals the restored count after
    /// [`Session::restore`]).
    pub fn outer(&self) -> usize {
        self.outer
    }

    pub fn kind(&self) -> AlgoKind {
        self.node.kind()
    }

    /// `Some(reason)` once the stop policy has fired.
    pub fn stopped(&self) -> Option<StopReason> {
        self.stopped
    }

    /// Execute one outer iteration (SPMD: all ranks in lockstep), then
    /// evaluate the stop policy.
    pub fn step(&mut self, ctx: &mut C) -> SessionStatus {
        if let Some(reason) = self.stopped {
            return SessionStatus::Stopped(reason, None);
        }
        if self.outer >= self.stop.max_outer {
            self.stopped = Some(StopReason::OuterCap);
            return SessionStatus::Stopped(StopReason::OuterCap, None);
        }
        let report = self.node.step(ctx, self.outer);
        self.outer += 1;
        if report.converged {
            self.stopped = Some(StopReason::Converged);
            return SessionStatus::Stopped(StopReason::Converged, Some(report));
        }
        if let Some(max_rounds) = self.stop.max_rounds {
            // The priced counters are identical on every rank (SPMD), so
            // this needs no extra communication.
            if ctx.comm_stats().rounds() >= max_rounds {
                self.stopped = Some(StopReason::RoundBudget);
                return SessionStatus::Stopped(StopReason::RoundBudget, Some(report));
            }
        }
        if let Some(budget) = self.stop.max_sim_seconds {
            // Clocks differ across ranks between collectives, so the
            // decision must be agreed on: one *free* metrics round (never
            // priced, never counted) carries the OR of the per-rank tests.
            let over = if ctx.clock() >= budget { 1.0 } else { 0.0 };
            let mut flag = vec![over];
            ctx.metric_reduce_all(&mut flag);
            if flag[0] > 0.0 {
                self.stopped = Some(StopReason::SimTimeBudget);
                return SessionStatus::Stopped(StopReason::SimTimeBudget, Some(report));
            }
        }
        SessionStatus::Running(report)
    }

    /// Drive until the stop policy fires, feeding each iteration's record
    /// to `on_iter` (rank-agnostic: every rank sees identical records).
    pub fn run_to_stop(
        &mut self,
        ctx: &mut C,
        mut on_iter: impl FnMut(&crate::algorithms::IterRecord),
    ) -> StopReason {
        loop {
            match self.step(ctx) {
                SessionStatus::Running(report) => on_iter(&report.record),
                SessionStatus::Stopped(reason, last) => {
                    if let Some(report) = last {
                        on_iter(&report.record);
                    }
                    return reason;
                }
            }
        }
    }

    /// Drain this rank's output (final iterate part, records, op counts).
    pub fn finish(self) -> NodeOutput {
        self.node.finish()
    }

    /// Serialize this rank's full resumable state (module docs describe
    /// the layout). Call at an iteration boundary only — i.e. between
    /// `step` calls — which is the only place the SPMD contract lets a
    /// driver run.
    pub fn checkpoint(&self, ctx: &C) -> Vec<u8> {
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(CKPT_MAGIC);
        put_u8(&mut buf, self.node.kind().code());
        put_u32(&mut buf, ctx.rank() as u32);
        put_u32(&mut buf, ctx.world() as u32);
        put_u64(&mut buf, self.outer as u64);
        match ctx.global_stats() {
            Some(stats) => {
                put_u8(&mut buf, 1);
                stats.encode(&mut buf);
            }
            None => put_u8(&mut buf, 0),
        }
        let st = ctx.export_state();
        put_f64(&mut buf, st.clock);
        st.stats.encode(&mut buf);
        match st.straggler {
            Some((rng, remaining)) => {
                put_u8(&mut buf, 1);
                for word in rng {
                    put_u64(&mut buf, word);
                }
                put_u32(&mut buf, remaining);
            }
            None => put_u8(&mut buf, 0),
        }
        put_u32(&mut buf, st.segments.len() as u32);
        for seg in &st.segments {
            seg.encode(&mut buf);
        }
        self.node.save_state(&mut buf);
        buf
    }

    /// Restore a checkpoint written by [`Session::checkpoint`] for the
    /// same `(spec, dataset, rank, world, transport kind)`. Replaces the
    /// context's clock/stats/trace and the solver state; the simulated
    /// clock is **not** advanced (setup side effects are discarded).
    pub fn restore(&mut self, ctx: &mut C, bytes: &[u8]) -> Result<(), String> {
        let mut r = ByteReader::new(bytes);
        let header = decode_header(&mut r)?;
        if header.algo != self.node.kind() {
            return Err(format!(
                "checkpoint is for {}, session runs {}",
                header.algo.name(),
                self.node.kind().name()
            ));
        }
        if header.rank != ctx.rank() || header.world != ctx.world() {
            return Err(format!(
                "checkpoint is for rank {}/{}, context is rank {}/{}",
                header.rank,
                header.world,
                ctx.rank(),
                ctx.world()
            ));
        }
        ctx.import_state(CtxState {
            clock: header.clock,
            stats: header.mirror,
            segments: header.segments,
            straggler: header.straggler,
        })?;
        self.node.restore_state(&mut r)?;
        r.finish()?;
        self.outer = header.outer;
        self.stopped = None;
        Ok(())
    }
}

struct CkptHeader {
    algo: AlgoKind,
    rank: usize,
    world: usize,
    outer: usize,
    global: Option<CommStats>,
    clock: f64,
    mirror: CommStats,
    straggler: Option<([u64; 4], u32)>,
    segments: Vec<Segment>,
}

fn decode_header(r: &mut ByteReader<'_>) -> Result<CkptHeader, String> {
    if r.take(4)? != CKPT_MAGIC {
        return Err("not a disco checkpoint (bad magic)".into());
    }
    let algo = AlgoKind::from_code(r.u8()?)?;
    let rank = r.u32()? as usize;
    let world = r.u32()? as usize;
    let outer = r.u64()? as usize;
    let global = if r.u8()? == 1 {
        Some(CommStats::decode(r)?)
    } else {
        None
    };
    let clock = r.f64()?;
    let mirror = CommStats::decode(r)?;
    let straggler = if r.u8()? == 1 {
        let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let remaining = r.u32()?;
        Some((rng, remaining))
    } else {
        None
    };
    let nseg = r.u32()? as usize;
    let mut segments = Vec::with_capacity(nseg);
    for _ in 0..nseg {
        segments.push(Segment::decode(r)?);
    }
    Ok(CkptHeader {
        algo,
        rank,
        world,
        outer,
        global,
        clock,
        mirror,
        straggler,
        segments,
    })
}

/// Read just the global-ledger snapshot out of a checkpoint blob (the shm
/// resume driver seeds the fresh blackboard with it before launching the
/// cluster; `None` for checkpoints written over tcp).
pub fn peek_global_stats(bytes: &[u8]) -> Result<Option<CommStats>, String> {
    let mut r = ByteReader::new(bytes);
    Ok(decode_header(&mut r)?.global)
}

// ---------------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------------

/// Where (and whether) a run saves / restores per-rank checkpoints. Rank
/// `r` uses `<prefix>.rank<r>`; under shm all files land on one machine,
/// under tcp each process touches only its own.
#[derive(Clone, Debug, Default)]
pub struct CheckpointPlan {
    /// Save before executing this outer iteration (0 = before the first).
    pub save_at: Option<usize>,
    /// Path prefix for the per-rank files.
    pub prefix: String,
    /// Restore from the per-rank files before stepping.
    pub resume: bool,
}

impl CheckpointPlan {
    /// No checkpointing at all.
    pub fn none() -> Self {
        Self::default()
    }

    /// Save once, before outer iteration `at`.
    pub fn save(prefix: &str, at: usize) -> Self {
        Self {
            save_at: Some(at),
            prefix: prefix.to_string(),
            resume: false,
        }
    }

    /// Resume from a previously saved prefix.
    pub fn resume(prefix: &str) -> Self {
        Self {
            save_at: None,
            prefix: prefix.to_string(),
            resume: true,
        }
    }

    pub fn rank_path(&self, rank: usize) -> String {
        format!("{}.rank{rank}", self.prefix)
    }

    fn is_none(&self) -> bool {
        self.save_at.is_none() && !self.resume
    }

    /// Declare the checkpoint/resume flags shared by the `disco` and
    /// `disco-node` binaries; parse them back with
    /// [`CheckpointPlan::from_args`].
    pub fn with_flags(args: crate::util::cli::Args) -> crate::util::cli::Args {
        args.opt("checkpoint-at", None, "save a checkpoint before this outer iteration (run)")
            .opt(
                "checkpoint",
                Some("results/ckpt"),
                "checkpoint prefix (per-rank files <prefix>.rankN)",
            )
            .opt("resume", None, "resume from this checkpoint path prefix (run)")
    }

    /// Build the plan from [`CheckpointPlan::with_flags`]. With `--resume`,
    /// its prefix is used for both reading and any later
    /// `--checkpoint-at` save.
    pub fn from_args(args: &crate::util::cli::Args) -> Result<CheckpointPlan, String> {
        let mut plan = CheckpointPlan::none();
        if args.provided("resume") {
            plan.resume = true;
            plan.prefix = args.req("resume").map_err(|e| e.to_string())?;
        }
        if args.provided("checkpoint-at") {
            plan.save_at = Some(args.get_usize("checkpoint-at").map_err(|e| e.to_string())?);
            if plan.prefix.is_empty() {
                plan.prefix = args.req("checkpoint").map_err(|e| e.to_string())?;
            }
        }
        Ok(plan)
    }
}

/// Per-rank driver: build (and optionally restore) a session, run it to
/// the stop policy, saving a checkpoint when the plan asks for one.
/// Shared verbatim by the shm thread cluster and the multi-process
/// transports — one loop, any backend.
pub fn drive_session<C: Collectives>(
    ctx: &mut C,
    ds: &Dataset,
    spec: &RunSpec,
    plan: &CheckpointPlan,
) -> Result<NodeOutput, String> {
    let mut session = Session::new(ctx, ds, spec);
    if plan.resume {
        let path = plan.rank_path(ctx.rank());
        let bytes =
            std::fs::read(&path).map_err(|e| format!("cannot read checkpoint '{path}': {e}"))?;
        session.restore(ctx, &bytes)?;
    }
    loop {
        if plan.save_at == Some(session.outer()) {
            let path = plan.rank_path(ctx.rank());
            if let Some(dir) = std::path::Path::new(&path).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)
                        .map_err(|e| format!("cannot create checkpoint dir: {e}"))?;
                }
            }
            std::fs::write(&path, session.checkpoint(ctx))
                .map_err(|e| format!("cannot write checkpoint '{path}': {e}"))?;
        }
        match session.step(ctx) {
            SessionStatus::Running(_) => {}
            SessionStatus::Stopped(..) => break,
        }
    }
    Ok(session.finish())
}

/// Run a spec over the in-process thread cluster (shm transport) — the
/// spec-driven counterpart of the legacy `algorithms::run`, which now
/// delegates here.
pub fn run_spec(ds: &Dataset, spec: &RunSpec) -> RunResult {
    run_spec_with(ds, spec, &CheckpointPlan::none())
}

/// [`run_spec`] with checkpoint/resume. Panics with `cluster node failed:
/// …` on any rank error (matching the cluster's failure contract).
pub fn run_spec_with(ds: &Dataset, spec: &RunSpec, plan: &CheckpointPlan) -> RunResult {
    if let Err(e) = spec.validate() {
        panic!("invalid run spec: {e}");
    }
    let mut cluster = spec.sim.cluster();
    if plan.resume {
        // Seed the global priced ledger from the checkpoint so its f64
        // accumulation continues the interrupted run bit-exactly.
        let path = plan.rank_path(0);
        let bytes = std::fs::read(&path)
            .unwrap_or_else(|e| panic!("cannot read checkpoint '{path}': {e}"));
        match peek_global_stats(&bytes).unwrap_or_else(|e| panic!("bad checkpoint '{path}': {e}"))
        {
            Some(stats) => cluster = cluster.with_initial_stats(stats),
            // A checkpoint without a global-ledger snapshot was written
            // over a transport whose ledger is the per-rank mirror (tcp).
            // Resuming it here would silently restart the shm blackboard
            // from zero and report inconsistent stats — refuse instead.
            None => panic!(
                "checkpoint '{path}' was written over a transport without a \
                 global ledger (tcp); resume it with --transport tcp"
            ),
        }
    }
    let plan = plan.clone();
    let run = cluster.run(|ctx| {
        if plan.is_none() {
            // Fast path without filesystem access.
            let mut session = Session::new(ctx, ds, spec);
            session.run_to_stop(ctx, |_| {});
            session.finish()
        } else {
            drive_session(ctx, ds, spec, &plan).unwrap_or_else(|e| panic!("{e}"))
        }
    });
    assemble(spec.kind(), run)
}

/// Run one rank's share of a spec over any [`Collectives`] backend — the
/// per-rank entry multi-process runs go through (no checkpointing).
pub fn node_run_spec<C: Collectives>(ctx: &mut C, ds: &Dataset, spec: &RunSpec) -> NodeOutput {
    let mut session = Session::new(ctx, ds, spec);
    session.run_to_stop(ctx, |_| {});
    session.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::RunConfig;
    use crate::data::SyntheticConfig;
    use crate::loss::LossKind;
    use crate::net::{Cluster, ComputeModel, CostModel};

    fn tiny() -> crate::data::Dataset {
        SyntheticConfig::new("t", 96, 48).density(0.2).seed(4).generate()
    }

    fn spec(kind: AlgoKind) -> RunSpec {
        let mut cfg = RunConfig::new(kind, LossKind::Logistic, 1e-2);
        cfg.m = 3;
        cfg.tau = 12;
        cfg.max_outer = 4;
        cfg.grad_tol = 0.0;
        cfg.compute = ComputeModel::modeled();
        cfg.cost = CostModel::default();
        cfg.to_spec()
    }

    #[test]
    fn session_steps_once_per_outer_iteration() {
        let ds = tiny();
        let spec = spec(AlgoKind::DiscoF);
        let run = Cluster::new(3).with_compute(ComputeModel::modeled()).run(|ctx| {
            let mut session = Session::new(ctx, &ds, &spec);
            let mut steps = 0usize;
            let reason = loop {
                match session.step(ctx) {
                    SessionStatus::Running(report) => {
                        assert_eq!(report.record.outer, steps);
                        steps += 1;
                    }
                    SessionStatus::Stopped(reason, last) => {
                        if last.is_some() {
                            steps += 1;
                        }
                        break reason;
                    }
                }
            };
            (steps, reason, session.finish())
        });
        for (steps, reason, out) in &run.outputs {
            assert_eq!(*steps, 4, "grad_tol 0 must exhaust the outer cap");
            assert_eq!(*reason, StopReason::OuterCap);
            // Records live on rank 0 only.
            assert!(out.records.len() == 4 || out.records.is_empty());
        }
    }

    #[test]
    fn round_budget_stops_early_and_agrees_across_ranks() {
        let ds = tiny();
        let mut s = spec(AlgoKind::DiscoS);
        s.stop.max_outer = 50;
        s.stop.max_rounds = Some(6);
        let res = run_spec(&ds, &s);
        assert!(!res.converged);
        assert!(
            res.records.len() < 50,
            "round budget should cut the run short"
        );
        // The budget fires on the post-step counters, which the final
        // stats reflect.
        assert!(res.stats.rounds() >= 6, "stopped before spending the budget");
    }

    #[test]
    fn sim_time_budget_stops_early() {
        let ds = tiny();
        let mut s = spec(AlgoKind::DiscoF);
        s.stop.max_outer = 50;
        // Modeled compute at default rate: a handful of iterations pass
        // this budget comfortably.
        s.stop.max_sim_seconds = Some(1e-9);
        let res = run_spec(&ds, &s);
        assert!(res.records.len() < 50);
        assert!(res.sim_seconds >= 1e-9);
    }

    #[test]
    fn checkpoint_restore_rejects_mismatches() {
        let ds = tiny();
        let spec_f = spec(AlgoKind::DiscoF);
        let spec_s = spec(AlgoKind::DiscoS);
        let run = Cluster::new(3).with_compute(ComputeModel::modeled()).run(|ctx| {
            let mut session = Session::new(ctx, &ds, &spec_f);
            let _ = session.step(ctx);
            let blob = session.checkpoint(ctx);
            // Wrong algorithm.
            let mut other = Session::new(ctx, &ds, &spec_s);
            let err = other.restore(ctx, &blob).unwrap_err();
            assert!(err.contains("DiSCO"), "{err}");
            // Truncated blob.
            let mut same = Session::new(ctx, &ds, &spec_f);
            assert!(same.restore(ctx, &blob[..blob.len() - 2]).is_err());
            // Garbage.
            assert!(same.restore(ctx, b"nope").is_err());
            0u8
        });
        assert_eq!(run.outputs.len(), 3);
    }
}
