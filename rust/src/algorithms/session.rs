//! Resumable step-wise run driver.
//!
//! A [`Session`] owns the outer loop that the legacy run-to-completion
//! entrypoints hid: each [`Session::step`] executes exactly one outer
//! iteration of the configured [`Algorithm`](crate::algorithms::Algorithm)
//! through the object-safe [`AlgorithmNode`] surface, then evaluates the
//! composable [`StopSpec`] policy (gradient tolerance ∧ outer cap ∧
//! simulated-time budget ∧ communication-round budget). Between steps the
//! caller can observe [`StepReport`]s, feed dashboards, or
//! [`Session::checkpoint`] the run.
//!
//! Sessions are **per-rank** objects, like everything else in the SPMD
//! runtime: every rank drives its own session in lockstep, and all stop
//! decisions derive from reduced scalars (or, for the simulated-time
//! budget, one *free* metrics round per iteration) so ranks can never
//! disagree.
//!
//! ## Checkpoint format
//!
//! [`Session::checkpoint`] serializes, per rank, through the
//! little-endian codec of [`crate::util::bytes`]:
//!
//! ```text
//! "DSK4" | algo u8 | rank u32 | world u32 | outer u64
//! cuts: ncuts u32, (lo u64, hi u64)*       (0 = the spec-default cut table)
//! global-ledger flag u8 [CommStats]        (shm blackboard snapshot)
//! clock f64 | busy f64 | serial f64 | CommStats mirror
//! straggler flag u8 [rng 4×u64, left u32]
//! trace: nseg u32, Segment*                (empty when tracing is off)
//! algorithm payload                        (AlgorithmNode::save_state)
//! ```
//!
//! (v4 widened the embedded [`CommStats`] codec with the unpriced wire
//! ledger; v3 added the serial busy-seconds scalar for serial-work-aware
//! speed estimation; older checkpoints are refused with a version
//! message. The structured event stream is deliberately *not*
//! checkpointed — events are diagnostics, not resumable state.)
//!
//! The cut table is recorded whenever the run had re-partitioned away
//! from the spec defaults (adaptive load balancing), so a resumed run
//! rebuilds its solver node on the cuts actually in force — without it,
//! the replicated-state algorithms would restore cleanly onto the wrong
//! shards and silently diverge.
//!
//! Everything *derivable* — shards, CSR mirrors, Woodbury factorizations —
//! is rebuilt on restore without touching the simulated clock, so under
//! [`ComputeModel::Modeled`](crate::net::ComputeModel) a resumed run is
//! **bit-identical** to an uninterrupted one: same records, same
//! `sim_seconds`, same traces, same [`CommStats`] (the shm global ledger
//! is re-seeded so its f64 accumulation *continues* rather than restarts
//! — see [`crate::net::Cluster::with_initial_stats`]). Restore a
//! checkpoint only on the transport kind that wrote it.

use crate::algorithms::algorithm::{AlgorithmNode, StepReport};
use crate::algorithms::repartition::Repartitioner;
use crate::algorithms::spec::{RepartitionSpec, RunSpec, StopSpec};
use crate::algorithms::{assemble, AlgoKind, NodeOutput, RunResult};
use crate::data::Dataset;
use crate::net::{Collectives, CommStats, CtxState, Segment};
use crate::obs::{EventKind, Phase};
use crate::util::bytes::{put_f64, put_u32, put_u64, put_u8, ByteReader};

const CKPT_MAGIC: &[u8; 4] = b"DSK4";

/// Why a session stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// ‖∇f‖ reached `stop.grad_tol`.
    Converged,
    /// `stop.max_outer` iterations ran.
    OuterCap,
    /// The simulated clock passed `stop.max_sim_seconds`.
    SimTimeBudget,
    /// `stop.max_rounds` vector rounds were spent.
    RoundBudget,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::Converged => "converged",
            StopReason::OuterCap => "outer-cap",
            StopReason::SimTimeBudget => "sim-time-budget",
            StopReason::RoundBudget => "round-budget",
        }
    }
}

/// Outcome of one [`Session::step`] call.
#[derive(Clone, Debug)]
pub enum SessionStatus {
    /// One outer iteration ran; the run continues.
    Running(StepReport),
    /// The run is over. When the final iteration executed during this call
    /// its report is attached; `None` means a pre-step policy (the outer
    /// cap) fired or the session was already stopped.
    Stopped(StopReason, Option<StepReport>),
}

/// Per-rank step-wise driver. See the module docs; construct with
/// [`Session::new`], advance with [`Session::step`], drain with
/// [`Session::finish`].
///
/// # Example
///
/// ```
/// use disco::algorithms::{run_spec, AlgoKind, RunSpec};
/// use disco::data::SyntheticConfig;
/// use disco::loss::LossKind;
///
/// let ds = SyntheticConfig::new("doc", 64, 24).density(0.3).seed(2).generate();
/// let mut spec = RunSpec::new(AlgoKind::Gd, LossKind::Quadratic, 1e-2);
/// spec.stop.max_outer = 5;
/// spec.stop.grad_tol = 0.0; // run all 5 iterations
/// let res = run_spec(&ds, &spec);
/// assert_eq!(res.records.len(), 5);
/// ```
pub struct Session<C: Collectives> {
    node: Box<dyn AlgorithmNode<C>>,
    stop: StopSpec,
    outer: usize,
    stopped: Option<StopReason>,
}

impl<C: Collectives> Session<C> {
    /// Build this rank's solver state for `spec` (runs
    /// [`Algorithm::setup`](crate::algorithms::Algorithm::setup), which
    /// costs the pre-loop compute through `ctx`).
    pub fn new(ctx: &mut C, ds: &Dataset, spec: &RunSpec) -> Session<C> {
        Session::with_cuts(ctx, ds, spec, None)
    }

    /// [`Session::new`] on an explicit cut table: resuming a checkpoint
    /// written after a mid-run re-cut must rebuild the solver node on the
    /// cuts in force at save time ([`peek_cuts`]), not the spec defaults.
    /// `None` = the spec-default cuts.
    pub fn with_cuts(
        ctx: &mut C,
        ds: &Dataset,
        spec: &RunSpec,
        cuts: Option<&[(usize, usize)]>,
    ) -> Session<C> {
        let algorithm = spec.algo.algorithm::<C>();
        // Store-backed setup reads shard files off disk; span it so the
        // IO shows up in traces. Unpriced and append-only (like every
        // event): the simulated clock and the run are bit-unaffected.
        let ingest_span = ctx.obs_enabled() && ds.x.is_store_backed();
        if ingest_span {
            ctx.obs_emit(EventKind::SpanBegin {
                phase: Phase::Ingest,
                label: "shard load".into(),
            });
        }
        let node = algorithm.setup(ctx, ds, spec, cuts);
        if ingest_span {
            ctx.obs_emit(EventKind::SpanEnd {
                phase: Phase::Ingest,
                label: "shard load".into(),
            });
        }
        Session {
            node,
            stop: spec.stop.clone(),
            outer: 0,
            stopped: None,
        }
    }

    /// Global cut-axis range of this rank's current shard.
    pub fn shard_range(&self) -> (usize, usize) {
        self.node.shard_range()
    }

    /// Modeled workload of this rank's current shard, in the units its
    /// cut policy balances (see [`AlgorithmNode::shard_work`]).
    pub fn shard_work(&self) -> f64 {
        self.node.shard_work()
    }

    /// Mid-run re-partition at an outer-iteration boundary: drain the
    /// current solver node, exchange the cut-axis state across ranks
    /// (one priced AllGather via
    /// [`Collectives::reshard_exchange`] — the re-shard traffic lands in
    /// the simulated timeline), set a fresh node up from the externally
    /// supplied cut table (costed like any setup: rebuilding shards and
    /// preconditioner factories is work the fleet genuinely redoes), and
    /// re-install the evolving solver state.
    ///
    /// SPMD contract: every rank must call this at the same boundary
    /// with the identical `ranges`. The outer counter and stop policy
    /// carry over; under the modeled clock the whole exchange is
    /// bit-deterministic across reruns and across transports.
    pub fn repartition(
        &mut self,
        ctx: &mut C,
        ds: &Dataset,
        spec: &RunSpec,
        ranges: &[(usize, usize)],
    ) -> Result<(), String> {
        let handoff = self.node.export_handoff();
        // Whether anything is sharded on the cut axis is a property of
        // the algorithm (identical on every rank), so skipping the
        // exchange for replicated-state methods needs no agreement round.
        let cut_axis = if handoff.cut_axis.is_empty() {
            Vec::new()
        } else {
            ctx.reshard_exchange(&handoff.cut_axis)
        };
        let algorithm = spec.algo.algorithm::<C>();
        // A re-cut over a store-backed dataset re-slices shard files on
        // the cut axis — span the IO like the initial shard load.
        let ingest_span = ctx.obs_enabled() && ds.x.is_store_backed();
        if ingest_span {
            ctx.obs_emit(EventKind::SpanBegin {
                phase: Phase::Ingest,
                label: "re-shard load".into(),
            });
        }
        let mut node = algorithm.setup(ctx, ds, spec, Some(ranges));
        if ingest_span {
            ctx.obs_emit(EventKind::SpanEnd {
                phase: Phase::Ingest,
                label: "re-shard load".into(),
            });
        }
        node.import_handoff(&cut_axis, &handoff.bytes)?;
        self.node = node;
        Ok(())
    }

    /// Non-destructive handoff snapshot of the live solver node (see
    /// [`AlgorithmNode::snapshot_handoff`]): the elastic driver keeps one
    /// per outer boundary as its rollback point. Free of communication
    /// and clock effects.
    pub fn snapshot_handoff(&self) -> crate::algorithms::algorithm::Handoff {
        self.node.snapshot_handoff()
    }

    /// Install handoff state into this session's freshly set-up node (the
    /// recovery half of [`Session::snapshot_handoff`]): `cut_axis` is the
    /// full re-assembled cut-axis vector, `bytes` the rank-local payload.
    pub fn import_handoff(&mut self, cut_axis: &[f64], bytes: &[u8]) -> Result<(), String> {
        self.node.import_handoff(cut_axis, bytes)
    }

    /// Reposition the outer counter after an elastic recovery rolled the
    /// solver state back to the boundary before `outer`, and clear any
    /// stop decision (the resumed loop re-evaluates the policy).
    pub fn resume_at(&mut self, outer: usize) {
        self.outer = outer;
        self.stopped = None;
    }

    /// Outer iterations completed so far (equals the restored count after
    /// [`Session::restore`]).
    pub fn outer(&self) -> usize {
        self.outer
    }

    pub fn kind(&self) -> AlgoKind {
        self.node.kind()
    }

    /// `Some(reason)` once the stop policy has fired.
    pub fn stopped(&self) -> Option<StopReason> {
        self.stopped
    }

    /// Execute one outer iteration (SPMD: all ranks in lockstep), then
    /// evaluate the stop policy.
    pub fn step(&mut self, ctx: &mut C) -> SessionStatus {
        if let Some(reason) = self.stopped {
            return SessionStatus::Stopped(reason, None);
        }
        if self.outer >= self.stop.max_outer {
            self.stopped = Some(StopReason::OuterCap);
            return SessionStatus::Stopped(StopReason::OuterCap, None);
        }
        // Event emission is append-only to rank-local memory (no clock,
        // stats, or collective effects), so instrumented and plain runs
        // stay bit-identical.
        let before = if ctx.obs_enabled() {
            ctx.obs_set_outer(self.outer as u32);
            ctx.obs_emit(EventKind::SpanBegin {
                phase: Phase::Outer,
                label: format!("outer {}", self.outer),
            });
            Some((ctx.comm_stats().clone(), ctx.overlap_seconds()))
        } else {
            None
        };
        let report = self.node.step(ctx, self.outer);
        self.outer += 1;
        if let Some((before, overlap_before)) = before {
            let after = ctx.comm_stats().clone();
            ctx.obs_emit(EventKind::Counter {
                rounds: after.vector_rounds - before.vector_rounds,
                scalar_rounds: after.scalar_rounds - before.scalar_rounds,
                doubles: after.vector_doubles - before.vector_doubles,
                comm_seconds: after.modeled_comm_seconds - before.modeled_comm_seconds,
                overlap_seconds: ctx.overlap_seconds() - overlap_before,
            });
            ctx.obs_emit(EventKind::Step {
                grad_norm: report.record.grad_norm,
                fval: report.record.fval,
                inner_iters: report.record.inner_iters as u32,
                rounds: after.vector_rounds,
            });
            ctx.obs_emit(EventKind::SpanEnd {
                phase: Phase::Outer,
                label: format!("outer {}", self.outer - 1),
            });
        }
        if report.converged {
            self.stopped = Some(StopReason::Converged);
            return SessionStatus::Stopped(StopReason::Converged, Some(report));
        }
        if let Some(max_rounds) = self.stop.max_rounds {
            // The priced counters are identical on every rank (SPMD), so
            // this needs no extra communication.
            if ctx.comm_stats().rounds() >= max_rounds {
                self.stopped = Some(StopReason::RoundBudget);
                return SessionStatus::Stopped(StopReason::RoundBudget, Some(report));
            }
        }
        if let Some(budget) = self.stop.max_sim_seconds {
            // Clocks differ across ranks between collectives, so the
            // decision must be agreed on: one *free* metrics round (never
            // priced, never counted) carries the OR of the per-rank tests.
            let over = if ctx.clock() >= budget { 1.0 } else { 0.0 };
            let mut flag = vec![over];
            ctx.metric_reduce_all(&mut flag);
            if flag[0] > 0.0 {
                self.stopped = Some(StopReason::SimTimeBudget);
                return SessionStatus::Stopped(StopReason::SimTimeBudget, Some(report));
            }
        }
        SessionStatus::Running(report)
    }

    /// Drive until the stop policy fires, feeding each iteration's record
    /// to `on_iter` (rank-agnostic: every rank sees identical records).
    pub fn run_to_stop(
        &mut self,
        ctx: &mut C,
        mut on_iter: impl FnMut(&crate::algorithms::IterRecord),
    ) -> StopReason {
        loop {
            match self.step(ctx) {
                SessionStatus::Running(report) => on_iter(&report.record),
                SessionStatus::Stopped(reason, last) => {
                    if let Some(report) = last {
                        on_iter(&report.record);
                    }
                    return reason;
                }
            }
        }
    }

    /// Drain this rank's output (final iterate part, records, op counts).
    pub fn finish(self) -> NodeOutput {
        self.node.finish()
    }

    /// Serialize this rank's full resumable state (module docs describe
    /// the layout). Call at an iteration boundary only — i.e. between
    /// `step` calls — which is the only place the SPMD contract lets a
    /// driver run.
    pub fn checkpoint(&self, ctx: &C) -> Vec<u8> {
        self.checkpoint_with_cuts(ctx, None)
    }

    /// [`Session::checkpoint`] recording a non-default cut table
    /// (adaptive re-partitioning): the restore driver feeds it back to
    /// [`Session::with_cuts`] so the rebuilt node shards exactly as the
    /// saved run did. `None` = the run is still on the spec-default cuts.
    pub fn checkpoint_with_cuts(&self, ctx: &C, cuts: Option<&[(usize, usize)]>) -> Vec<u8> {
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(CKPT_MAGIC);
        put_u8(&mut buf, self.node.kind().code());
        put_u32(&mut buf, ctx.rank() as u32);
        put_u32(&mut buf, ctx.world() as u32);
        put_u64(&mut buf, self.outer as u64);
        match cuts {
            None => put_u32(&mut buf, 0),
            Some(cuts) => {
                put_u32(&mut buf, cuts.len() as u32);
                for &(lo, hi) in cuts {
                    put_u64(&mut buf, lo as u64);
                    put_u64(&mut buf, hi as u64);
                }
            }
        }
        match ctx.global_stats() {
            Some(stats) => {
                put_u8(&mut buf, 1);
                stats.encode(&mut buf);
            }
            None => put_u8(&mut buf, 0),
        }
        let st = ctx.export_state();
        put_f64(&mut buf, st.clock);
        put_f64(&mut buf, st.compute_seconds);
        put_f64(&mut buf, st.serial_seconds);
        st.stats.encode(&mut buf);
        match st.straggler {
            Some((rng, remaining)) => {
                put_u8(&mut buf, 1);
                for word in rng {
                    put_u64(&mut buf, word);
                }
                put_u32(&mut buf, remaining);
            }
            None => put_u8(&mut buf, 0),
        }
        put_u32(&mut buf, st.segments.len() as u32);
        for seg in &st.segments {
            seg.encode(&mut buf);
        }
        self.node.save_state(&mut buf);
        buf
    }

    /// Restore a checkpoint written by [`Session::checkpoint`] for the
    /// same `(spec, dataset, rank, world, transport kind)`. Replaces the
    /// context's clock/stats/trace and the solver state; the simulated
    /// clock is **not** advanced (setup side effects are discarded).
    pub fn restore(&mut self, ctx: &mut C, bytes: &[u8]) -> Result<(), String> {
        let mut r = ByteReader::new(bytes);
        let header = decode_header(&mut r)?;
        if header.algo != self.node.kind() {
            return Err(format!(
                "checkpoint is for {}, session runs {}",
                header.algo.name(),
                self.node.kind().name()
            ));
        }
        if header.rank != ctx.rank() || header.world != ctx.world() {
            return Err(format!(
                "checkpoint is for rank {}/{}, context is rank {}/{}",
                header.rank,
                header.world,
                ctx.rank(),
                ctx.world()
            ));
        }
        // A checkpoint written after a mid-run re-cut records the cut
        // table in force; the session must have been set up on it
        // (`Session::with_cuts` + [`peek_cuts`]). Refusing here keeps the
        // replicated-state algorithms — whose serialized vectors are
        // full-length and would pass every size check — from silently
        // resuming onto the wrong shards.
        if let Some(cuts) = &header.cuts {
            let expect = cuts.get(header.rank).copied();
            if expect != Some(self.node.shard_range()) {
                return Err(format!(
                    "checkpoint was saved on cut {:?} for rank {}, session shards {:?}; \
                     rebuild the session from the checkpoint's cut table (peek_cuts)",
                    expect,
                    header.rank,
                    self.node.shard_range()
                ));
            }
        }
        ctx.import_state(CtxState {
            clock: header.clock,
            compute_seconds: header.compute_seconds,
            serial_seconds: header.serial_seconds,
            stats: header.mirror,
            segments: header.segments,
            straggler: header.straggler,
        })?;
        self.node.restore_state(&mut r)?;
        r.finish()?;
        self.outer = header.outer;
        self.stopped = None;
        Ok(())
    }
}

struct CkptHeader {
    algo: AlgoKind,
    rank: usize,
    world: usize,
    outer: usize,
    cuts: Option<Vec<(usize, usize)>>,
    global: Option<CommStats>,
    clock: f64,
    compute_seconds: f64,
    serial_seconds: f64,
    mirror: CommStats,
    straggler: Option<([u64; 4], u32)>,
    segments: Vec<Segment>,
}

fn decode_header(r: &mut ByteReader<'_>) -> Result<CkptHeader, String> {
    let magic = r.take(4)?;
    if magic != CKPT_MAGIC {
        if magic == b"DSK3" {
            return Err(
                "checkpoint format v3 (pre unpriced-wire accounting); re-save with this build"
                    .into(),
            );
        }
        if magic == b"DSK2" {
            return Err(
                "checkpoint format v2 (pre serial-accounting); re-save with this build".into(),
            );
        }
        return Err("not a disco checkpoint (bad magic)".into());
    }
    let algo = AlgoKind::from_code(r.u8()?)?;
    let rank = r.u32()? as usize;
    let world = r.u32()? as usize;
    let outer = r.u64()? as usize;
    let ncuts = r.u32()? as usize;
    let cuts = if ncuts == 0 {
        None
    } else {
        if ncuts != world {
            return Err(format!("checkpoint cut table has {ncuts} ranges for world {world}"));
        }
        let mut cuts = Vec::with_capacity(ncuts);
        for _ in 0..ncuts {
            cuts.push((r.u64()? as usize, r.u64()? as usize));
        }
        Some(cuts)
    };
    let global = if r.u8()? == 1 {
        Some(CommStats::decode(r)?)
    } else {
        None
    };
    let clock = r.f64()?;
    let compute_seconds = r.f64()?;
    let serial_seconds = r.f64()?;
    let mirror = CommStats::decode(r)?;
    let straggler = if r.u8()? == 1 {
        let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let remaining = r.u32()?;
        Some((rng, remaining))
    } else {
        None
    };
    let nseg = r.u32()? as usize;
    let mut segments = Vec::with_capacity(nseg);
    for _ in 0..nseg {
        segments.push(Segment::decode(r)?);
    }
    Ok(CkptHeader {
        algo,
        rank,
        world,
        outer,
        cuts,
        global,
        clock,
        compute_seconds,
        serial_seconds,
        mirror,
        straggler,
        segments,
    })
}

/// Read just the global-ledger snapshot out of a checkpoint blob (the shm
/// resume driver seeds the fresh blackboard with it before launching the
/// cluster; `None` for checkpoints written over tcp).
pub fn peek_global_stats(bytes: &[u8]) -> Result<Option<CommStats>, String> {
    let mut r = ByteReader::new(bytes);
    Ok(decode_header(&mut r)?.global)
}

/// Read just the recorded cut table out of a checkpoint blob (`None` =
/// the run was on the spec-default cuts). The resume driver feeds this to
/// [`Session::with_cuts`] so the rebuilt node shards as the saved run did.
pub fn peek_cuts(bytes: &[u8]) -> Result<Option<Vec<(usize, usize)>>, String> {
    let mut r = ByteReader::new(bytes);
    Ok(decode_header(&mut r)?.cuts)
}

// ---------------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------------

/// Where (and whether) a run saves / restores per-rank checkpoints. Rank
/// `r` uses `<prefix>.rank<r>` for the one-shot save and
/// `<prefix>.o<outer>.rank<r>` for periodic saves; under shm all files
/// land on one machine, under tcp each process touches only its own.
/// Saves and resume reads have independent prefixes: resuming a periodic
/// save (`--resume <prefix>.o<k>`) with `--checkpoint <prefix>` keeps
/// new saves — and the rotation window — in the original file series
/// instead of nesting under the resume path.
#[derive(Clone, Debug, Default)]
pub struct CheckpointPlan {
    /// Save before executing this outer iteration (0 = before the first).
    pub save_at: Option<usize>,
    /// Also save before every `k`-th outer iteration (k ≥ 1), to
    /// outer-tagged files — long (and adaptive) runs checkpoint
    /// periodically instead of once.
    pub save_every: Option<usize>,
    /// Rotation: keep only the newest `keep` periodic saves per rank,
    /// deleting older `<prefix>.o<outer>.rank<r>` files as new ones land
    /// (0 = keep everything). One-shot `save_at` files are never rotated.
    pub keep: usize,
    /// Path prefix for the per-rank save files.
    pub prefix: String,
    /// Resume source: path prefix whose per-rank files are restored
    /// before stepping (`None` = fresh run).
    pub resume_from: Option<String>,
}

impl CheckpointPlan {
    /// No checkpointing at all.
    pub fn none() -> Self {
        Self::default()
    }

    /// Save once, before outer iteration `at`.
    pub fn save(prefix: &str, at: usize) -> Self {
        Self {
            save_at: Some(at),
            prefix: prefix.to_string(),
            ..Self::default()
        }
    }

    /// Save before every `k`-th outer iteration, keeping the newest
    /// `keep` files per rank (0 = all).
    pub fn save_every(prefix: &str, every: usize, keep: usize) -> Self {
        assert!(every >= 1, "periodic saves need a period of at least 1");
        Self {
            save_every: Some(every),
            keep,
            prefix: prefix.to_string(),
            ..Self::default()
        }
    }

    /// Resume from a previously saved prefix (which doubles as the save
    /// prefix for any later saves, the legacy behaviour — set
    /// [`CheckpointPlan::prefix`] separately to keep saving in another
    /// series).
    pub fn resume(prefix: &str) -> Self {
        Self {
            prefix: prefix.to_string(),
            resume_from: Some(prefix.to_string()),
            ..Self::default()
        }
    }

    pub fn rank_path(&self, rank: usize) -> String {
        format!("{}.rank{rank}", self.prefix)
    }

    /// Per-rank path of the periodic save taken before `outer`. Resuming
    /// one is `--resume <prefix>.o<outer>`.
    pub fn rank_path_at(&self, outer: usize, rank: usize) -> String {
        format!("{}.o{outer}.rank{rank}", self.prefix)
    }

    /// Per-rank path this run resumes from, when it does.
    pub fn resume_rank_path(&self, rank: usize) -> Option<String> {
        self.resume_from.as_ref().map(|p| format!("{p}.rank{rank}"))
    }

    fn is_none(&self) -> bool {
        self.save_at.is_none() && self.save_every.is_none() && self.resume_from.is_none()
    }

    /// Declare the checkpoint/resume flags shared by the `disco` and
    /// `disco-node` binaries; parse them back with
    /// [`CheckpointPlan::from_args`].
    pub fn with_flags(args: crate::util::cli::Args) -> crate::util::cli::Args {
        args.opt("checkpoint-at", None, "save a checkpoint before this outer iteration (run)")
            .opt(
                "checkpoint",
                Some("results/ckpt"),
                "checkpoint prefix (per-rank files <prefix>.rankN)",
            )
            .opt(
                "checkpoint-every",
                None,
                "also save before every k-th outer iteration (<prefix>.o<k>.rankN)",
            )
            .opt(
                "checkpoint-keep",
                Some("0"),
                "rotation: keep only the newest N periodic checkpoints per rank (0 = all)",
            )
            .opt("resume", None, "resume from this checkpoint path prefix (run)")
    }

    /// Build the plan from [`CheckpointPlan::with_flags`]. An explicit
    /// `--checkpoint` prefix always names the save series; without one,
    /// `--resume`'s prefix doubles as the save prefix (legacy) — so a
    /// resumed periodic run should pass `--checkpoint <orig>` to keep
    /// rotating the original `<orig>.o<k>` files.
    pub fn from_args(args: &crate::util::cli::Args) -> Result<CheckpointPlan, String> {
        let mut plan = CheckpointPlan::none();
        if args.provided("resume") {
            plan.resume_from = Some(args.req("resume").map_err(|e| e.to_string())?);
        }
        if args.provided("checkpoint-at") {
            plan.save_at = Some(args.get_usize("checkpoint-at").map_err(|e| e.to_string())?);
        }
        if args.provided("checkpoint-every") {
            let every = args.get_usize("checkpoint-every").map_err(|e| e.to_string())?;
            if every == 0 {
                return Err("--checkpoint-every must be at least 1".into());
            }
            plan.save_every = Some(every);
        }
        plan.keep = args.get_usize("checkpoint-keep").map_err(|e| e.to_string())?;
        if plan.save_at.is_some() || plan.save_every.is_some() {
            plan.prefix = if !args.provided("checkpoint") && plan.resume_from.is_some() {
                plan.resume_from.clone().unwrap()
            } else {
                args.req("checkpoint").map_err(|e| e.to_string())?
            };
        }
        Ok(plan)
    }
}

/// Rotation bookkeeping for periodic saves: `saved` lists the outers with
/// a save on disk, oldest first, the newest just appended; returns the
/// outers whose files must be deleted so only the newest `keep` remain
/// (`keep = 0` keeps everything).
fn rotate_out(saved: &mut Vec<usize>, keep: usize) -> Vec<usize> {
    if keep == 0 || saved.len() <= keep {
        return Vec::new();
    }
    let drop = saved.len() - keep;
    saved.drain(..drop).collect()
}

/// Write one rank's checkpoint blob, creating parent directories.
fn write_checkpoint(path: &str, bytes: &[u8]) -> Result<(), String> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("cannot create checkpoint dir: {e}"))?;
        }
    }
    std::fs::write(path, bytes).map_err(|e| format!("cannot write checkpoint '{path}': {e}"))
}

/// Per-rank driver: build (and optionally restore) a session, run it to
/// the stop policy, saving checkpoints when the plan asks for them and
/// letting the [`Repartitioner`] re-cut the partition from measured
/// speeds when its trigger fires. Shared verbatim by the shm thread
/// cluster and the multi-process transports — one loop, any backend.
/// Returns this rank's output plus the number of re-cuts performed
/// (identical on every rank — the trigger decides on reduced data).
///
/// Combining `--resume` with adaptive re-partitioning is supported: the
/// checkpoint records the cut table in force, the restored session is
/// rebuilt on it, and the repartitioner adopts it as its baseline
/// (test-enforced bit-identical continuation in
/// `integration_adaptive.rs`). One caveat: the observation window's
/// *phase* restarts at the resume point, so a resumed run is
/// bit-identical to the uninterrupted one when the save landed on a
/// window boundary (always true for `--repartition-every 1`, and for
/// `--checkpoint-every` periods that are multiples of the window);
/// otherwise the first post-resume check just happens up to `every − 1`
/// iterations later — still deterministic, merely phase-shifted.
pub fn drive_session<C: Collectives>(
    ctx: &mut C,
    ds: &Dataset,
    spec: &RunSpec,
    plan: &CheckpointPlan,
    repartition: &RepartitionSpec,
) -> Result<(NodeOutput, usize), String> {
    // Resume reads the blob first: a checkpoint written after a mid-run
    // re-cut records the cut table in force, and the fresh node must be
    // set up on it (the spec defaults would silently put the
    // replicated-state algorithms on the wrong shards).
    let resume_bytes = match plan.resume_rank_path(ctx.rank()) {
        Some(path) => Some(
            std::fs::read(&path).map_err(|e| format!("cannot read checkpoint '{path}': {e}"))?,
        ),
        None => None,
    };
    let mut active_cuts = match &resume_bytes {
        Some(bytes) => peek_cuts(bytes)?,
        None => None,
    };
    let mut session = Session::with_cuts(ctx, ds, spec, active_cuts.as_deref());
    if let Some(bytes) = &resume_bytes {
        session.restore(ctx, bytes)?;
    }
    let mut balancer = Repartitioner::new(ctx, ds, spec, repartition.clone());
    if let Some(cuts) = &active_cuts {
        balancer.set_ranges(cuts.clone());
    }
    // Rotation bookkeeping spans interrupt + resume cycles: a *resumed*
    // run seeds it with the periodic saves already on disk in its save
    // series (oldest first). Fresh runs start empty — files left by an
    // unrelated earlier run under the same prefix are not this run's to
    // rotate.
    let mut saved: Vec<usize> = if plan.resume_from.is_some() && plan.save_every.is_some() {
        saved_outers(plan, ctx.rank())
    } else {
        Vec::new()
    };
    // Enforce `keep` on what the interrupted run left behind right away —
    // a resumed run that tightened the budget (or stops before its next
    // fresh boundary) must not strand extra files. Safe even if this
    // prunes the resume source: its bytes are already in memory.
    for old in rotate_out(&mut saved, plan.keep) {
        let _ = std::fs::remove_file(plan.rank_path_at(old, ctx.rank()));
    }
    loop {
        let outer = session.outer();
        if plan.save_at == Some(outer) {
            write_checkpoint(
                &plan.rank_path(ctx.rank()),
                &session.checkpoint_with_cuts(ctx, active_cuts.as_deref()),
            )?;
        }
        if let Some(every) = plan.save_every {
            if outer > 0 && outer % every == 0 {
                // Always (re)write — idempotent for faithful resumes,
                // corrective otherwise; the bookkeeping dedups so a
                // re-executed boundary keeps its original rotation slot.
                write_checkpoint(
                    &plan.rank_path_at(outer, ctx.rank()),
                    &session.checkpoint_with_cuts(ctx, active_cuts.as_deref()),
                )?;
                if !saved.contains(&outer) {
                    saved.push(outer);
                    for old in rotate_out(&mut saved, plan.keep) {
                        // Best-effort prune: a hand-deleted file is fine.
                        let _ = std::fs::remove_file(plan.rank_path_at(old, ctx.rank()));
                    }
                }
            }
        }
        match session.step(ctx) {
            SessionStatus::Running(_) => {
                if balancer.after_step(ctx, &mut session, ds, spec)? {
                    active_cuts = Some(balancer.ranges().to_vec());
                }
            }
            SessionStatus::Stopped(..) => break,
        }
    }
    Ok((session.finish(), balancer.recuts()))
}

/// Outers with a periodic save on disk for `rank` under `plan`'s prefix,
/// sorted ascending — rotation bookkeeping survives interrupt + resume
/// cycles instead of restarting empty and stranding old files.
fn saved_outers(plan: &CheckpointPlan, rank: usize) -> Vec<usize> {
    let prefix = std::path::Path::new(&plan.prefix);
    let dir = match prefix.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let Some(base) = prefix.file_name().and_then(|s| s.to_str()) else {
        return Vec::new();
    };
    let suffix = format!(".rank{rank}");
    let mut outers = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(rest) = name.strip_prefix(base) else { continue };
            let Some(tag) = rest.strip_prefix(".o") else { continue };
            let Some(num) = tag.strip_suffix(&suffix) else { continue };
            if let Ok(outer) = num.parse::<usize>() {
                outers.push(outer);
            }
        }
    }
    outers.sort_unstable();
    outers
}

/// Run a spec over the in-process thread cluster (shm transport) — the
/// spec-driven counterpart of the legacy `algorithms::run`, which now
/// delegates here.
pub fn run_spec(ds: &Dataset, spec: &RunSpec) -> RunResult {
    run_spec_with(ds, spec, &CheckpointPlan::none())
}

/// [`run_spec`] with checkpoint/resume. Panics with `cluster node failed:
/// …` on any rank error (matching the cluster's failure contract).
pub fn run_spec_with(ds: &Dataset, spec: &RunSpec, plan: &CheckpointPlan) -> RunResult {
    run_spec_full(ds, spec, plan, &RepartitionSpec::none()).0
}

/// [`run_spec`] with adaptive mid-run re-partitioning; returns the result
/// plus the number of re-cuts the driver performed.
pub fn run_spec_adaptive(
    ds: &Dataset,
    spec: &RunSpec,
    repartition: &RepartitionSpec,
) -> (RunResult, usize) {
    run_spec_full(ds, spec, &CheckpointPlan::none(), repartition)
}

/// The full shm driver: checkpoint plan + adaptive re-partitioning.
/// Panics with `cluster node failed: …` on any rank error (matching the
/// cluster's failure contract). The returned count is the number of
/// mid-run re-cuts (0 when the trigger is disabled or never fires).
pub fn run_spec_full(
    ds: &Dataset,
    spec: &RunSpec,
    plan: &CheckpointPlan,
    repartition: &RepartitionSpec,
) -> (RunResult, usize) {
    if let Err(e) = spec.validate() {
        panic!("invalid run spec: {e}");
    }
    let mut cluster = spec.sim.cluster();
    if let Some(path) = plan.resume_rank_path(0) {
        // Seed the global priced ledger from the checkpoint so its f64
        // accumulation continues the interrupted run bit-exactly.
        let bytes = std::fs::read(&path)
            .unwrap_or_else(|e| panic!("cannot read checkpoint '{path}': {e}"));
        match peek_global_stats(&bytes).unwrap_or_else(|e| panic!("bad checkpoint '{path}': {e}"))
        {
            Some(stats) => cluster = cluster.with_initial_stats(stats),
            // A checkpoint without a global-ledger snapshot was written
            // over a transport whose ledger is the per-rank mirror (tcp).
            // Resuming it here would silently restart the shm blackboard
            // from zero and report inconsistent stats — refuse instead.
            None => panic!(
                "checkpoint '{path}' was written over a transport without a \
                 global ledger (tcp); resume it with --transport tcp"
            ),
        }
    }
    let plan = plan.clone();
    let rp = repartition.clone();
    let run = cluster.run(|ctx| {
        if plan.is_none() && !rp.enabled() {
            // Fast path without filesystem access or balancing probes.
            let mut session = Session::new(ctx, ds, spec);
            session.run_to_stop(ctx, |_| {});
            (session.finish(), 0usize)
        } else {
            drive_session(ctx, ds, spec, &plan, &rp).unwrap_or_else(|e| panic!("{e}"))
        }
    });
    // Re-cut count is identical on every rank (SPMD trigger on reduced
    // data); report rank 0's.
    let recuts = run.outputs.first().map(|(_, r)| *r).unwrap_or(0);
    let run = crate::net::ClusterRun {
        outputs: run.outputs.into_iter().map(|(out, _)| out).collect(),
        stats: run.stats,
        trace: run.trace,
        sim_seconds: run.sim_seconds,
        wall_seconds: run.wall_seconds,
        events: run.events,
    };
    (assemble(spec.kind(), run), recuts)
}

/// Run one rank's share of a spec over any [`Collectives`] backend — the
/// per-rank entry multi-process runs go through (no checkpointing).
pub fn node_run_spec<C: Collectives>(ctx: &mut C, ds: &Dataset, spec: &RunSpec) -> NodeOutput {
    let mut session = Session::new(ctx, ds, spec);
    session.run_to_stop(ctx, |_| {});
    session.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::RunConfig;
    use crate::data::SyntheticConfig;
    use crate::loss::LossKind;
    use crate::net::{Cluster, ComputeModel, CostModel};

    fn tiny() -> crate::data::Dataset {
        SyntheticConfig::new("t", 96, 48).density(0.2).seed(4).generate()
    }

    fn spec(kind: AlgoKind) -> RunSpec {
        let mut cfg = RunConfig::new(kind, LossKind::Logistic, 1e-2);
        cfg.m = 3;
        cfg.tau = 12;
        cfg.max_outer = 4;
        cfg.grad_tol = 0.0;
        cfg.compute = ComputeModel::modeled();
        cfg.cost = CostModel::default();
        cfg.to_spec()
    }

    #[test]
    fn session_steps_once_per_outer_iteration() {
        let ds = tiny();
        let spec = spec(AlgoKind::DiscoF);
        let run = Cluster::new(3).with_compute(ComputeModel::modeled()).run(|ctx| {
            let mut session = Session::new(ctx, &ds, &spec);
            let mut steps = 0usize;
            let reason = loop {
                match session.step(ctx) {
                    SessionStatus::Running(report) => {
                        assert_eq!(report.record.outer, steps);
                        steps += 1;
                    }
                    SessionStatus::Stopped(reason, last) => {
                        if last.is_some() {
                            steps += 1;
                        }
                        break reason;
                    }
                }
            };
            (steps, reason, session.finish())
        });
        for (steps, reason, out) in &run.outputs {
            assert_eq!(*steps, 4, "grad_tol 0 must exhaust the outer cap");
            assert_eq!(*reason, StopReason::OuterCap);
            // Records live on rank 0 only.
            assert!(out.records.len() == 4 || out.records.is_empty());
        }
    }

    #[test]
    fn round_budget_stops_early_and_agrees_across_ranks() {
        let ds = tiny();
        let mut s = spec(AlgoKind::DiscoS);
        s.stop.max_outer = 50;
        s.stop.max_rounds = Some(6);
        let res = run_spec(&ds, &s);
        assert!(!res.converged);
        assert!(
            res.records.len() < 50,
            "round budget should cut the run short"
        );
        // The budget fires on the post-step counters, which the final
        // stats reflect.
        assert!(res.stats.rounds() >= 6, "stopped before spending the budget");
    }

    #[test]
    fn sim_time_budget_stops_early() {
        let ds = tiny();
        let mut s = spec(AlgoKind::DiscoF);
        s.stop.max_outer = 50;
        // Modeled compute at default rate: a handful of iterations pass
        // this budget comfortably.
        s.stop.max_sim_seconds = Some(1e-9);
        let res = run_spec(&ds, &s);
        assert!(res.records.len() < 50);
        assert!(res.sim_seconds >= 1e-9);
    }

    #[test]
    fn rotation_keeps_only_the_newest_saves() {
        let mut saved = Vec::new();
        // keep = 0: nothing is ever rotated out.
        for outer in [2, 4, 6] {
            saved.push(outer);
            assert!(rotate_out(&mut saved, 0).is_empty());
        }
        assert_eq!(saved, vec![2, 4, 6]);
        // keep = 2: each new save beyond the window evicts the oldest.
        let mut saved = Vec::new();
        let mut evicted = Vec::new();
        for outer in [2, 4, 6, 8, 10] {
            saved.push(outer);
            evicted.extend(rotate_out(&mut saved, 2));
        }
        assert_eq!(saved, vec![8, 10], "newest two stay on disk");
        assert_eq!(evicted, vec![2, 4, 6], "older saves pruned oldest-first");
        // keep larger than what exists: no-op.
        let mut saved = vec![3];
        assert!(rotate_out(&mut saved, 5).is_empty());
        assert_eq!(saved, vec![3]);
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn checkpoint_plan_flags_parse_rotation() {
        let schema = CheckpointPlan::with_flags(crate::util::cli::Args::new("t", "t"));
        let a = schema
            .clone()
            .parse(&argv(&["--checkpoint-every", "5", "--checkpoint-keep", "3"]))
            .unwrap();
        let plan = CheckpointPlan::from_args(&a).unwrap();
        assert_eq!(plan.save_every, Some(5));
        assert_eq!(plan.keep, 3);
        assert_eq!(plan.prefix, "results/ckpt", "default prefix applies");
        assert!(!plan.is_none());
        assert_eq!(plan.rank_path_at(10, 2), "results/ckpt.o10.rank2");
        // A zero period is rejected; keep defaults to 0 (keep all).
        let a = schema
            .clone()
            .parse(&argv(&["--checkpoint-every", "0"]))
            .unwrap();
        assert!(CheckpointPlan::from_args(&a).is_err());
        // An explicit --checkpoint names the save series even when
        // resuming — the resumed run keeps rotating the original
        // <prefix>.o<k> files instead of nesting under the resume path.
        let a = schema
            .clone()
            .parse(&argv(&[
                "--resume",
                "c.o4",
                "--checkpoint",
                "c",
                "--checkpoint-every",
                "2",
            ]))
            .unwrap();
        let plan = CheckpointPlan::from_args(&a).unwrap();
        assert_eq!(plan.resume_from.as_deref(), Some("c.o4"));
        assert_eq!(plan.prefix, "c");
        // Without it, the resume prefix doubles as the save prefix
        // (legacy behaviour).
        let a = schema
            .clone()
            .parse(&argv(&["--resume", "c.o4", "--checkpoint-at", "9"]))
            .unwrap();
        assert_eq!(CheckpointPlan::from_args(&a).unwrap().prefix, "c.o4");
        let a = schema.parse(&argv(&["--checkpoint-at", "3"])).unwrap();
        assert_eq!(CheckpointPlan::from_args(&a).unwrap().keep, 0);
    }

    #[test]
    fn periodic_saves_rotate_on_disk() {
        let ds = tiny();
        let mut s = spec(AlgoKind::Gd);
        s.stop.max_outer = 7;
        let prefix = format!(
            "{}/disco_session_rotation/ckpt",
            std::env::temp_dir().display()
        );
        let _ = std::fs::remove_dir_all(std::path::Path::new(&prefix).parent().unwrap());
        let plan = CheckpointPlan::save_every(&prefix, 2, 2);
        let res = run_spec_with(&ds, &s, &plan);
        assert_eq!(res.records.len(), 7);
        // Saves land before outers 2, 4, 6; keep = 2 leaves only 4 and 6.
        for rank in 0..s.sim.m {
            assert!(!std::path::Path::new(&plan.rank_path_at(2, rank)).exists());
            assert!(std::path::Path::new(&plan.rank_path_at(4, rank)).exists());
            assert!(std::path::Path::new(&plan.rank_path_at(6, rank)).exists());
        }
        assert_eq!(saved_outers(&plan, 0), vec![4, 6]);
        assert_eq!(saved_outers(&plan, 9), Vec::<usize>::new());
        // A periodic save resumes like any checkpoint — bit-identically.
        let resumed = run_spec_with(&ds, &s, &CheckpointPlan::resume(&format!("{prefix}.o4")));
        assert_eq!(resumed.records.len(), res.records.len());
        for (a, b) in resumed.records.iter().zip(res.records.iter()) {
            assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        }
        assert_eq!(resumed.sim_seconds.to_bits(), res.sim_seconds.to_bits());
        // Rotation bookkeeping reloads from disk on resume, so the `keep`
        // window keeps sliding over the original file series: resume from
        // o4 with a longer cap (saves stay in `prefix`'s series) — the
        // new o8 save evicts o4; re-executed boundaries (o4, o6) keep
        // their original rotation slots instead of double-counting.
        let mut s9 = s.clone();
        s9.stop.max_outer = 9;
        let resume_plan = CheckpointPlan {
            save_at: None,
            save_every: Some(2),
            keep: 2,
            prefix: prefix.clone(),
            resume_from: Some(format!("{prefix}.o4")),
        };
        let long = run_spec_with(&ds, &s9, &resume_plan);
        assert_eq!(long.records.len(), 9);
        for rank in 0..s.sim.m {
            assert!(!std::path::Path::new(&plan.rank_path_at(4, rank)).exists());
            assert!(std::path::Path::new(&plan.rank_path_at(6, rank)).exists());
            assert!(std::path::Path::new(&plan.rank_path_at(8, rank)).exists());
        }
        assert_eq!(saved_outers(&plan, 0), vec![6, 8]);
    }

    #[test]
    fn checkpoint_restore_rejects_mismatches() {
        let ds = tiny();
        let spec_f = spec(AlgoKind::DiscoF);
        let spec_s = spec(AlgoKind::DiscoS);
        let run = Cluster::new(3).with_compute(ComputeModel::modeled()).run(|ctx| {
            let mut session = Session::new(ctx, &ds, &spec_f);
            let _ = session.step(ctx);
            let blob = session.checkpoint(ctx);
            // Wrong algorithm.
            let mut other = Session::new(ctx, &ds, &spec_s);
            let err = other.restore(ctx, &blob).unwrap_err();
            assert!(err.contains("DiSCO"), "{err}");
            // Truncated blob.
            let mut same = Session::new(ctx, &ds, &spec_f);
            assert!(same.restore(ctx, &blob[..blob.len() - 2]).is_err());
            // Garbage.
            assert!(same.restore(ctx, b"nope").is_err());
            0u8
        });
        assert_eq!(run.outputs.len(), 3);
    }
}
