//! Shared machinery for the distributed algorithms: Hessian subsampling
//! masks (Fig. 5), preconditioner sample selection, the damped-Newton step,
//! and the per-iteration metric recorder.

use crate::algorithms::spec::{DiscoParams, RunSpec};
use crate::algorithms::{IterRecord, OpCounts};
use crate::data::{balanced_ranges, weighted_ranges, Dataset, Partition, PartitionKind};
use crate::linalg::DataMatrix;
use crate::loss::Loss;
use crate::net::Collectives;
use crate::util::bytes::{put_f64, put_f64s, put_u32, put_u64, put_u8, ByteReader};
use crate::util::prng::Xoshiro256pp;

/// Block count for the split-phase (overlapped) PCG sweeps
/// (`SimSpec::overlap`): with B blocks only the last block's bandwidth
/// term is exposed (saved ≈ bw·(1−1/B), see DESIGN.md §3), so returns
/// diminish quickly; 4 keeps per-block latency and handle bookkeeping
/// negligible. `block_ranges` clamps to the sweep dimension, so tiny
/// problems degrade gracefully.
pub(crate) const OVERLAP_BLOCKS: usize = 4;

/// Per-row overhead (in nnz-equivalent flops) of a DiSCO-F PCG step
/// beyond the HVP sweeps: ≈2τ of Woodbury apply plus ~10 of vector
/// updates. One definition shared by the setup-time cut policy and the
/// repartitioner's re-cut, so they can never drift.
pub(crate) fn feature_row_overhead(p: &DiscoParams) -> f64 {
    2.0 * p.tau as f64 + 10.0
}

/// The deterministic default cut table for `spec` — the exact ranges
/// `Algorithm::setup` shards by when no external cut is supplied, and the
/// repartitioner's notion of "the current partition" before any re-cut.
/// Every rank computes the identical table (pure function of `ds` +
/// `spec`), then extracts only its own shard, so the thread cluster and
/// the per-process TCP ranks can never diverge on shard boundaries.
pub(crate) fn default_cuts(ds: &Dataset, spec: &RunSpec) -> Vec<(usize, usize)> {
    match spec.kind().cut_axis() {
        PartitionKind::Features => {
            let p = spec
                .algo
                .disco()
                .expect("feature-partitioned algorithms carry DiscoParams");
            let row_overhead = feature_row_overhead(p);
            match spec.sim.partition_speeds() {
                // Heterogeneous fleet: equalize modeled work ÷ speed.
                Some(speeds) => Partition::feature_cost_cuts(ds, speeds, row_overhead),
                None if p.balanced_partition => {
                    Partition::feature_cost_cuts(ds, &vec![1.0; spec.sim.m], row_overhead)
                }
                None => balanced_ranges(ds.dim(), spec.sim.m),
            }
        }
        PartitionKind::Samples => match spec.sim.partition_speeds() {
            Some(speeds) => weighted_ranges(ds.nsamples(), speeds),
            None => balanced_ranges(ds.nsamples(), spec.sim.m),
        },
    }
}

/// Resolve the cut table an `Algorithm::setup` shards by: the externally
/// supplied one (adaptive re-cut) or the spec default.
pub(crate) fn resolve_cuts(
    ds: &Dataset,
    spec: &RunSpec,
    ranges: Option<&[(usize, usize)]>,
) -> Vec<(usize, usize)> {
    match ranges {
        Some(r) => {
            assert_eq!(r.len(), spec.sim.m, "external cut table must have one range per rank");
            r.to_vec()
        }
        None => default_cuts(ds, spec),
    }
}

/// Forcing term for the inexact Newton solve:
/// `ε_k = β·‖∇f(w_k)‖` (Zhang & Xiao's relative criterion), floored so the
/// last outer iterations don't demand more than the global tolerance.
pub fn forcing(grad_norm: f64, beta: f64, grad_tol: f64) -> f64 {
    (beta * grad_norm).max(0.1 * grad_tol)
}

/// Damped Newton step scale `1/(1+δ_k)` with `δ_k = √(v_kᵀ H v_k)`
/// (Algorithm 1 line 6).
pub fn damped_scale(vhv: f64) -> f64 {
    1.0 / (1.0 + vhv.max(0.0).sqrt())
}

/// Per-outer-iteration Hessian sample mask (Fig. 5): selects
/// `⌈fraction·n⌉` of the n **global** sample indices, identically on every
/// node (seeded by `seed ⊕ outer`). Returns `None` for fraction = 1
/// (exact Hessian — the default fast path).
pub struct HessianSubsample {
    pub fraction: f64,
    pub seed: u64,
}

impl HessianSubsample {
    /// Build the 0/1 mask and its effective count for outer iteration `k`.
    pub fn mask(&self, n: usize, outer: usize) -> Option<(Vec<bool>, usize)> {
        if self.fraction >= 1.0 {
            return None;
        }
        let h = ((self.fraction * n as f64).ceil() as usize).clamp(1, n);
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed ^ (outer as u64).wrapping_mul(0x9E37));
        let idx = rng.sample_indices(n, h);
        let mut mask = vec![false; n];
        for i in idx {
            mask[i] = true;
        }
        Some((mask, h))
    }
}

/// Apply loss second-derivatives (optionally masked) to margins, producing
/// the HVP scaling vector `s` and its effective divisor. With a mask, the
/// Hessian estimate is `(1/h) Σ_{i∈S} s_i x_i x_iᵀ` (unbiased for
/// uniform S).
pub fn hessian_scalings(
    loss: &dyn Loss,
    z: &[f64],
    y: &[f64],
    mask: Option<&(Vec<bool>, usize)>,
    n_global: usize,
) -> (Vec<f64>, f64) {
    debug_assert_eq!(z.len(), y.len());
    match mask {
        None => (
            z.iter()
                .zip(y.iter())
                .map(|(zi, yi)| loss.second_deriv(*zi, *yi))
                .collect(),
            n_global as f64,
        ),
        Some((m, h)) => (
            z.iter()
                .zip(y.iter())
                .enumerate()
                .map(|(i, (zi, yi))| {
                    if m[i] {
                        loss.second_deriv(*zi, *yi)
                    } else {
                        0.0
                    }
                })
                .collect(),
            *h as f64,
        ),
    }
}

/// Preconditioner sample selection: the paper uses the master's first τ
/// samples (Eq. 5, "subset of data available on master node"). We take the
/// first τ *global* indices — which live on the master under sample
/// partitioning and are feature-sliced across all nodes under feature
/// partitioning — so DiSCO-S and DiSCO-F precondition with the *same*
/// matrix (block-diagonal restriction for F).
pub fn precond_sample_count(tau: usize, available: usize) -> usize {
    tau.min(available)
}

/// Densify preconditioner columns `0..tau` of a shard.
pub fn precond_columns(x: &DataMatrix, tau: usize) -> Vec<Vec<f64>> {
    (0..precond_sample_count(tau, x.ncols()))
        .map(|j| x.col_dense(j))
        .collect()
}

/// Metric recorder driven by node 0 inside the SPMD closure. The gradient
/// norm / objective value come from the algorithm (usually free as a
/// by-product or via the metrics channel); rounds and simulated time come
/// from the node's local mirrors.
pub struct Recorder {
    pub records: Vec<IterRecord>,
    enabled: bool,
}

impl Recorder {
    /// Only node 0's recorder is enabled; other nodes keep an empty one so
    /// the SPMD code is rank-agnostic.
    pub fn new(rank: usize) -> Self {
        Self {
            records: Vec::new(),
            enabled: rank == 0,
        }
    }

    /// True on the rank whose records are authoritative (rank 0) — the
    /// rank that also reports the full iterate for the replicated-iterate
    /// algorithms.
    pub fn is_primary(&self) -> bool {
        self.enabled
    }

    /// Build this iteration's record (every rank computes the identical
    /// one: the inputs are reduced scalars, the synchronized clock, and
    /// the rank-mirrored counters); rank 0 also appends it to its list.
    /// The returned record feeds [`crate::algorithms::StepReport`].
    pub fn push(
        &mut self,
        ctx: &impl Collectives,
        outer: usize,
        grad_norm: f64,
        fval: f64,
        inner: usize,
    ) -> IterRecord {
        let stats = ctx.comm_stats();
        let record = IterRecord {
            outer,
            rounds: stats.vector_rounds,
            scalar_rounds: stats.scalar_rounds,
            vector_doubles: stats.vector_doubles,
            sim_time: ctx.clock(),
            grad_norm,
            fval,
            inner_iters: inner,
        };
        if self.enabled {
            self.records.push(record.clone());
        }
        record
    }
}

// ---------------------------------------------------------------------------
// Checkpoint codec helpers shared by the AlgorithmNode implementations
// ---------------------------------------------------------------------------

pub(crate) fn put_bool(buf: &mut Vec<u8>, b: bool) {
    put_u8(buf, u8::from(b));
}

pub(crate) fn read_bool(r: &mut ByteReader<'_>) -> Result<bool, String> {
    match r.u8()? {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(format!("bad bool byte {other}")),
    }
}

/// Length-prefixed f64 vector.
pub(crate) fn put_vec(buf: &mut Vec<u8>, v: &[f64]) {
    put_u32(buf, v.len() as u32);
    put_f64s(buf, v);
}

/// Read a length-prefixed f64 vector *into* `v`, enforcing that the
/// checkpointed length matches the freshly set-up buffer (a mismatch means
/// the checkpoint belongs to a different dataset/partition).
pub(crate) fn read_vec_into(r: &mut ByteReader<'_>, v: &mut Vec<f64>) -> Result<(), String> {
    let len = r.u32()? as usize;
    if len != v.len() {
        return Err(format!(
            "checkpoint vector has {len} entries, this run expects {}",
            v.len()
        ));
    }
    *v = r.f64s(len)?;
    Ok(())
}

pub(crate) fn encode_records(buf: &mut Vec<u8>, records: &[IterRecord]) {
    put_u32(buf, records.len() as u32);
    for rec in records {
        put_u64(buf, rec.outer as u64);
        put_u64(buf, rec.rounds);
        put_u64(buf, rec.scalar_rounds);
        put_u64(buf, rec.vector_doubles);
        put_f64(buf, rec.sim_time);
        put_f64(buf, rec.grad_norm);
        put_f64(buf, rec.fval);
        put_u64(buf, rec.inner_iters as u64);
    }
}

pub(crate) fn decode_records(r: &mut ByteReader<'_>) -> Result<Vec<IterRecord>, String> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(IterRecord {
            outer: r.u64()? as usize,
            rounds: r.u64()?,
            scalar_rounds: r.u64()?,
            vector_doubles: r.u64()?,
            sim_time: r.f64()?,
            grad_norm: r.f64()?,
            fval: r.f64()?,
            inner_iters: r.u64()? as usize,
        });
    }
    Ok(out)
}

pub(crate) fn encode_ops(buf: &mut Vec<u8>, ops: &OpCounts) {
    put_u64(buf, ops.hvp);
    put_u64(buf, ops.precond_solve);
    put_u64(buf, ops.axpy);
    put_u64(buf, ops.dot);
    put_u64(buf, ops.dim as u64);
}

pub(crate) fn decode_ops(r: &mut ByteReader<'_>) -> Result<OpCounts, String> {
    Ok(OpCounts {
        hvp: r.u64()?,
        precond_solve: r.u64()?,
        axpy: r.u64()?,
        dot: r.u64()?,
        dim: r.u64()? as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Logistic, Quadratic};

    #[test]
    fn forcing_scales_with_gradient() {
        assert!((forcing(1.0, 0.05, 1e-9) - 0.05).abs() < 1e-15);
        // Floors at a tenth of the global tolerance.
        assert!((forcing(1e-12, 0.05, 1e-9) - 1e-10).abs() < 1e-24);
    }

    #[test]
    fn damped_scale_bounds() {
        assert_eq!(damped_scale(0.0), 1.0);
        assert!((damped_scale(4.0) - 1.0 / 3.0).abs() < 1e-15);
        // Negative (numerical noise) clamps to full step.
        assert_eq!(damped_scale(-1e-18), 1.0);
    }

    #[test]
    fn subsample_mask_counts_and_determinism() {
        let hs = HessianSubsample {
            fraction: 0.25,
            seed: 9,
        };
        let (m1, h1) = hs.mask(100, 3).unwrap();
        let (m2, h2) = hs.mask(100, 3).unwrap();
        assert_eq!(h1, 25);
        assert_eq!(h2, 25);
        assert_eq!(m1, m2, "mask must be identical across nodes");
        assert_eq!(m1.iter().filter(|&&b| b).count(), h1);
        let (m3, _) = hs.mask(100, 4).unwrap();
        assert_ne!(m1, m3, "mask must change across outer iterations");
    }

    #[test]
    fn full_fraction_returns_none() {
        let hs = HessianSubsample {
            fraction: 1.0,
            seed: 1,
        };
        assert!(hs.mask(50, 0).is_none());
    }

    #[test]
    fn scalings_respect_mask() {
        let z = vec![0.0, 1.0, -1.0, 0.5];
        let y = vec![1.0, 1.0, -1.0, 1.0];
        let (s, div) = hessian_scalings(&Quadratic, &z, &y, None, 4);
        assert_eq!(s, vec![2.0; 4]);
        assert_eq!(div, 4.0);
        let mask = (vec![true, false, true, false], 2usize);
        let (s2, div2) = hessian_scalings(&Logistic, &z, &y, Some(&mask), 4);
        assert_eq!(div2, 2.0);
        assert_eq!(s2[1], 0.0);
        assert_eq!(s2[3], 0.0);
        assert!(s2[0] > 0.0 && s2[2] > 0.0);
    }

    #[test]
    fn precond_columns_cap_at_available() {
        assert_eq!(precond_sample_count(100, 30), 30);
        assert_eq!(precond_sample_count(10, 30), 10);
    }
}
