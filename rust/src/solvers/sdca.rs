//! Stochastic Dual Coordinate Ascent — the local solver inside the CoCoA+
//! baseline (paper §1.1 item 4 and §5.2: "SDCA was used as the solver for
//! subproblems").
//!
//! The node-local state is the dual block `α_j` for the shard's samples
//! plus the implied primal contribution `Δv = (1/λn) X_j Δα_j`. CoCoA+
//! with *adding* (γ = 1) requires the local subproblem curvature scaled by
//! `σ' = m` (Ma et al. 2015b), which appears below as `sigma` multiplying
//! the quadratic term of each coordinate step.

use crate::linalg::DataMatrix;
use crate::loss::Loss;
use crate::util::prng::Xoshiro256pp;

/// Node-local SDCA state for one shard. The struct owns only the evolving
/// solver state (the dual block `α_j` plus cached column norms); the shard
/// data and loss are passed to each call, so distributed drivers can hold
/// the state in a long-lived per-rank object — and serialize `alpha` into
/// a session checkpoint — without self-referential borrows.
pub struct SdcaLocal {
    /// Global regularization λ and global sample count n.
    pub lambda: f64,
    pub n_global: usize,
    /// CoCoA+ subproblem scaling σ′ (= m for adding).
    pub sigma: f64,
    /// Dual variables for this shard's samples.
    pub alpha: Vec<f64>,
    /// Precomputed ‖x_i‖².
    norms_sq: Vec<f64>,
}

impl SdcaLocal {
    pub fn new(x: &DataMatrix, lambda: f64, n_global: usize, sigma: f64) -> Self {
        let n_local = x.ncols();
        let norms_sq = (0..n_local).map(|j| x.col_norm_sq(j)).collect();
        Self {
            lambda,
            n_global,
            sigma,
            alpha: vec![0.0; n_local],
            norms_sq,
        }
    }

    /// Run `epochs` passes of SDCA against the (fixed) global iterate `w`.
    /// Returns the accumulated primal delta `Δv = (1/λn) X_j Δα_j` that
    /// CoCoA+ aggregates with one ReduceAll. `x`/`y` must be the shard the
    /// state was built for.
    ///
    /// Margins are computed against `w + σ′·Δv_local`, the "adding"
    /// subproblem's local view of the moving iterate.
    pub fn epoch(
        &mut self,
        x: &DataMatrix,
        y: &[f64],
        loss: &dyn Loss,
        w: &[f64],
        epochs: usize,
        rng: &mut Xoshiro256pp,
    ) -> Vec<f64> {
        let d = x.nrows();
        assert_eq!(w.len(), d);
        let n_local = self.alpha.len();
        assert_eq!(x.ncols(), n_local, "shard does not match the SDCA state");
        assert_eq!(y.len(), n_local);
        let inv_ln = 1.0 / (self.lambda * self.n_global as f64);
        let mut dv = vec![0.0; d];
        // w_local = w + σ′·Δv, maintained incrementally.
        let mut w_local = w.to_vec();
        for _ in 0..epochs {
            for _ in 0..n_local {
                let j = rng.index(n_local);
                let z = x.col_dot(j, &w_local);
                let q = self.sigma * self.norms_sq[j] * inv_ln;
                let delta = loss.sdca_delta(y[j], z, self.alpha[j], q);
                if delta == 0.0 {
                    continue;
                }
                self.alpha[j] += delta;
                let coef = delta * inv_ln;
                x.col_axpy(j, coef, &mut dv);
                x.col_axpy(j, self.sigma * coef, &mut w_local);
            }
        }
        dv
    }

    /// Local dual objective contribution `−(1/n) Σ φ*(−α_i)` (the ‖v‖² part
    /// is global and added by the caller).
    pub fn dual_data_term(&self, y: &[f64], loss: &dyn Loss) -> f64 {
        let mut s = 0.0;
        for (a, yi) in self.alpha.iter().zip(y.iter()) {
            s -= loss.conjugate(-a, *yi);
        }
        s / self.n_global as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ops, CscMatrix};
    use crate::loss::{Logistic, Objective, Quadratic};

    /// Single-machine SDCA (m=1, σ′=1) must converge to the primal optimum.
    fn run_single_machine(loss: &dyn Loss, seed: u64) -> (f64, f64) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = 10;
        let n = 60;
        let x = DataMatrix::Sparse(CscMatrix::rand_sparse(d, n, 0.5, &mut rng));
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let lambda = 0.05;
        let mut local = SdcaLocal::new(&x, lambda, n, 1.0);
        let mut w = vec![0.0; d];
        for _ in 0..80 {
            let dv = local.epoch(&x, &y, loss, &w, 1, &mut rng);
            for (wi, di) in w.iter_mut().zip(dv.iter()) {
                *wi += di;
            }
        }
        // Primal optimality: ‖∇f(w)‖ should be small.
        let obj = Objective::new(&x, &y, loss, lambda);
        let g = obj.grad(&w);
        (ops::norm2(&g), obj.value(&w))
    }

    #[test]
    fn sdca_converges_quadratic() {
        let (gnorm, _) = run_single_machine(&Quadratic, 11);
        assert!(gnorm < 1e-3, "‖∇f‖ = {gnorm}");
    }

    #[test]
    fn sdca_converges_logistic() {
        let (gnorm, _) = run_single_machine(&Logistic, 12);
        assert!(gnorm < 1e-3, "‖∇f‖ = {gnorm}");
    }

    #[test]
    fn duality_gap_shrinks() {
        // D(α) ≤ P(w) always; the gap must shrink over epochs.
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let d = 8;
        let n = 40;
        let x = DataMatrix::Sparse(CscMatrix::rand_sparse(d, n, 0.5, &mut rng));
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let lambda = 0.1;
        let loss = Quadratic;
        let obj = Objective::new(&x, &y, &loss, lambda);
        let mut local = SdcaLocal::new(&x, lambda, n, 1.0);
        let mut w = vec![0.0; d];
        let mut gaps = Vec::new();
        for _ in 0..30 {
            let dv = local.epoch(&x, &y, &loss, &w, 1, &mut rng);
            for (wi, di) in w.iter_mut().zip(dv.iter()) {
                *wi += di;
            }
            let primal = obj.value(&w);
            let dual = local.dual_data_term(&y, &loss) - 0.5 * lambda * ops::norm2_sq(&w);
            let gap = primal - dual;
            assert!(gap > -1e-9, "weak duality violated: {gap}");
            gaps.push(gap);
        }
        assert!(gaps[29] < gaps[0] * 0.05, "gap did not shrink: {gaps:?}");
    }

    #[test]
    fn sigma_scaling_keeps_multinode_updates_safe() {
        // Two shards updated independently with σ′=2 then added must keep
        // the DUAL objective monotonically ascending (the CoCoA+ safety
        // property; the primal value is not pointwise monotone) and reach
        // a small primal gradient.
        let mut rng = Xoshiro256pp::seed_from_u64(14);
        let d = 8;
        let n = 60;
        let x = DataMatrix::Sparse(CscMatrix::rand_sparse(d, n, 0.5, &mut rng));
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let lambda = 0.05;
        let loss = Quadratic;
        let obj = Objective::new(&x, &y, &loss, lambda);
        let xa = x.col_block(0, 30);
        let xb = x.col_block(30, 60);
        let mut la = SdcaLocal::new(&xa, lambda, n, 2.0);
        let mut lb = SdcaLocal::new(&xb, lambda, n, 2.0);
        let mut w = vec![0.0; d];
        let mut prev_dual = f64::NEG_INFINITY;
        for it in 0..40 {
            let da = la.epoch(&xa, &y[..30], &loss, &w, 1, &mut rng);
            let db = lb.epoch(&xb, &y[30..], &loss, &w, 1, &mut rng);
            for i in 0..d {
                w[i] += da[i] + db[i];
            }
            let dual = la.dual_data_term(&y[..30], &loss) + lb.dual_data_term(&y[30..], &loss)
                - 0.5 * lambda * ops::norm2_sq(&w);
            assert!(
                dual >= prev_dual - 1e-9,
                "dual decreased at iter {it}: {prev_dual} → {dual}"
            );
            // Weak duality.
            assert!(dual <= obj.value(&w) + 1e-9);
            prev_dual = dual;
        }
        let g = obj.grad(&w);
        assert!(ops::norm2(&g) < 0.05, "far from optimum: {}", ops::norm2(&g));
    }
}
