//! Single-machine (preconditioned) conjugate gradients.
//!
//! This is the *reference* PCG used to validate the distributed
//! implementations (Algorithms 2 and 3 produce, in exact arithmetic, the
//! same iterates as this solver applied to the aggregated system) and by
//! the single-node reference Newton solver.

use crate::linalg::ops;

/// Abstract SPD operator `y = A x`.
pub trait LinearOperator {
    fn dim(&self) -> usize;
    fn apply_into(&self, x: &[f64], y: &mut [f64]);

    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim()];
        self.apply_into(x, &mut y);
        y
    }
}

/// Abstract preconditioner apply `y = M⁻¹ x`.
pub trait Preconditioner {
    fn apply_into(&self, x: &[f64], y: &mut [f64]);
}

/// Identity preconditioner (plain CG).
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(x);
    }
}

impl Preconditioner for crate::solvers::woodbury::Woodbury {
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        crate::solvers::woodbury::Woodbury::apply_into(self, x, y)
    }
}

/// Dense matrix as operator (tests).
impl LinearOperator for crate::linalg::SquareMatrix {
    fn dim(&self) -> usize {
        self.n()
    }
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        self.mul_into(x, y)
    }
}

/// Outcome of a PCG solve.
#[derive(Clone, Debug)]
pub struct PcgResult {
    pub v: Vec<f64>,
    /// `H v` at the solution (needed for the Newton decrement δ).
    pub hv: Vec<f64>,
    pub iterations: usize,
    pub residual_norm: f64,
    pub converged: bool,
}

/// Convergence facts of an in-place solve ([`pcg_into`]); the iterate
/// itself stays in the caller's [`PcgScratch`].
#[derive(Clone, Copy, Debug)]
pub struct PcgStats {
    pub iterations: usize,
    pub residual_norm: f64,
    pub converged: bool,
}

/// Caller-owned PCG work vectors. Allocate once (per Newton solve, per
/// node, …) and hand to [`pcg_into`] repeatedly: no PCG iteration — and
/// no repeated solve — touches the heap.
#[derive(Clone, Debug)]
pub struct PcgScratch {
    /// Solution iterate (valid after `pcg_into` returns).
    pub v: Vec<f64>,
    /// `A·v`, tracked incrementally (Algorithm 2 line 6).
    pub hv: Vec<f64>,
    r: Vec<f64>,
    s: Vec<f64>,
    u: Vec<f64>,
    hu: Vec<f64>,
}

impl PcgScratch {
    pub fn new(n: usize) -> Self {
        Self {
            v: vec![0.0; n],
            hv: vec![0.0; n],
            r: vec![0.0; n],
            s: vec![0.0; n],
            u: vec![0.0; n],
            hu: vec![0.0; n],
        }
    }

    pub fn dim(&self) -> usize {
        self.v.len()
    }
}

/// Solve `A v = b` to `‖r‖ ≤ tol`, at most `max_iter` steps, with
/// preconditioner `M⁻¹`, entirely inside `ws` (no allocation). Follows
/// the paper's Algorithm 2 update order (tracks `Hv` incrementally,
/// line 6). The solution is left in `ws.v` (with `A·v` in `ws.hv`).
pub fn pcg_into(
    a: &impl LinearOperator,
    b: &[f64],
    m_inv: &impl Preconditioner,
    tol: f64,
    max_iter: usize,
    ws: &mut PcgScratch,
) -> PcgStats {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(ws.dim(), n, "scratch sized for a different system");
    ops::zero(&mut ws.v);
    ops::zero(&mut ws.hv);
    ws.r.copy_from_slice(b); // r_0 = b − A·0
    m_inv.apply_into(&ws.r, &mut ws.s);
    ws.u.copy_from_slice(&ws.s);
    let mut rs = ops::dot(&ws.r, &ws.s);
    let mut iterations = 0;
    let mut rnorm = ops::norm2(&ws.r);

    while rnorm > tol && iterations < max_iter {
        a.apply_into(&ws.u, &mut ws.hu);
        let uhu = ops::dot(&ws.u, &ws.hu);
        if uhu <= 0.0 {
            // Operator not PD along u (numerical breakdown) — bail with
            // the current iterate rather than diverging.
            break;
        }
        let alpha = rs / uhu;
        ops::axpy(alpha, &ws.u, &mut ws.v);
        ops::axpy(alpha, &ws.hu, &mut ws.hv);
        ops::axpy(-alpha, &ws.hu, &mut ws.r);
        m_inv.apply_into(&ws.r, &mut ws.s);
        let rs_new = ops::dot(&ws.r, &ws.s);
        rnorm = ops::norm2(&ws.r);
        iterations += 1;
        if rs_new == 0.0 {
            // The preconditioned residual vanished exactly. Either we are
            // done (r = 0) or M⁻¹ annihilated a nonzero residual
            // (rank-deficient/indefinite preconditioner); in both cases
            // β = rs_new/rs next round would be 0/0 → NaN poisoning every
            // vector. Break cleanly with the current iterate.
            break;
        }
        let beta = rs_new / rs;
        rs = rs_new;
        ops::axpby(1.0, &ws.s, beta, &mut ws.u);
    }
    PcgStats {
        iterations,
        residual_norm: rnorm,
        converged: rnorm <= tol,
    }
}

/// Allocating convenience wrapper around [`pcg_into`].
pub fn pcg(
    a: &impl LinearOperator,
    b: &[f64],
    m_inv: &impl Preconditioner,
    tol: f64,
    max_iter: usize,
) -> PcgResult {
    let mut ws = PcgScratch::new(a.dim());
    let stats = pcg_into(a, b, m_inv, tol, max_iter, &mut ws);
    PcgResult {
        v: ws.v,
        hv: ws.hv,
        iterations: stats.iterations,
        residual_norm: stats.residual_norm,
        converged: stats.converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SquareMatrix;
    use crate::solvers::woodbury::Woodbury;
    use crate::util::prng::Xoshiro256pp;

    fn spd(n: usize, seed: u64, cond_boost: f64) -> SquareMatrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = SquareMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a.set(i, j, s / n as f64 + if i == j { cond_boost } else { 0.0 });
            }
        }
        a
    }

    #[test]
    fn cg_solves_spd_system() {
        let n = 30;
        let a = spd(n, 1, 0.5);
        let xtrue: Vec<f64> = (0..n).map(|i| (i as f64 * 0.2).sin()).collect();
        let b = a.mul(&xtrue);
        let res = pcg(&a, &b, &IdentityPrecond, 1e-10, 500);
        assert!(res.converged, "residual {}", res.residual_norm);
        for (x, t) in res.v.iter().zip(&xtrue) {
            assert!((x - t).abs() < 1e-7);
        }
        // hv tracked incrementally must equal A·v.
        let av = a.mul(&res.v);
        for (h, t) in res.hv.iter().zip(&av) {
            assert!((h - t).abs() < 1e-7);
        }
    }

    #[test]
    fn exact_preconditioner_converges_in_one_iteration() {
        // If M = A exactly, PCG must converge in a single step.
        let n = 12;
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let cols: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let w = vec![0.7; 6];
        let wb = Woodbury::new(n, &cols, &w, 0.4).unwrap();
        let a = wb.dense(); // operator IS the preconditioner
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let res = pcg(&a, &b, &wb, 1e-9, 50);
        assert!(res.converged);
        assert_eq!(res.iterations, 1, "exact preconditioning must take 1 step");
    }

    #[test]
    fn good_preconditioner_beats_plain_cg() {
        // A = P + small perturbation ⇒ PCG(P) needs far fewer iterations.
        let n = 40;
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let cols: Vec<Vec<f64>> = (0..20)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let w = vec![0.5; 20];
        let wb = Woodbury::new(n, &cols, &w, 0.05).unwrap();
        let mut a = wb.dense();
        for i in 0..n {
            a.add_to(i, i, 0.01 * (1.0 + (i as f64 * 0.4).sin().abs()));
        }
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let plain = pcg(&a, &b, &IdentityPrecond, 1e-8, 2000);
        let pre = pcg(&a, &b, &wb, 1e-8, 2000);
        assert!(pre.converged && plain.converged);
        assert!(
            pre.iterations * 2 <= plain.iterations,
            "PCG {} vs CG {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn max_iter_respected() {
        let a = spd(25, 3, 0.01);
        let b = vec![1.0; 25];
        let res = pcg(&a, &b, &IdentityPrecond, 1e-16, 3);
        assert_eq!(res.iterations, 3);
        assert!(!res.converged);
    }

    /// Rank-1 "preconditioner" that annihilates every coordinate but the
    /// first — after one step the preconditioned residual is exactly zero
    /// while ‖r‖ > 0.
    struct E1Projector;
    impl Preconditioner for E1Projector {
        fn apply_into(&self, x: &[f64], y: &mut [f64]) {
            for v in y.iter_mut() {
                *v = 0.0;
            }
            y[0] = x[0];
        }
    }

    /// 90° rotation: sᵀr = 0 always, so rs = 0 from the very first
    /// iteration while s (and hence u) is nonzero — the exact setup where
    /// the unguarded β = rs_new/rs division turns 0/0 into NaN and
    /// poisons every PCG vector.
    struct Rotator;
    impl Preconditioner for Rotator {
        fn apply_into(&self, x: &[f64], y: &mut [f64]) {
            y[0] = -x[1];
            y[1] = x[0];
        }
    }

    #[test]
    fn vanishing_preconditioned_residual_breaks_cleanly() {
        // A = diag(1, 2), b = [1, 1]: step 1 solves the e1 component
        // exactly, then M⁻¹r = 0 with r = [0, 1] ≠ 0. The solver must
        // stop after that one step with a finite iterate.
        let mut a = SquareMatrix::zeros(2);
        a.set(0, 0, 1.0);
        a.set(1, 1, 2.0);
        let res = pcg(&a, &[1.0, 1.0], &E1Projector, 1e-12, 50);
        assert_eq!(res.iterations, 1, "must break at the vanishing rs");
        assert!(!res.converged);
        assert!(res.v.iter().all(|v| v.is_finite()));
        assert!((res.v[0] - 1.0).abs() < 1e-12);
        assert!((res.residual_norm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_preconditioner_never_produces_nan() {
        let a = spd(2, 8, 0.3);
        let res = pcg(&a, &[1.0, 0.0], &Rotator, 1e-12, 100);
        assert!(res.v.iter().all(|v| v.is_finite()), "iterate poisoned: {:?}", res.v);
        assert!(res.hv.iter().all(|v| v.is_finite()));
        assert!(res.residual_norm.is_finite());
        assert!(res.iterations <= 1, "must stop once rs vanishes");
    }

    #[test]
    fn pcg_into_reuses_scratch_across_solves() {
        let n = 20;
        let a = spd(n, 6, 0.4);
        let mut ws = PcgScratch::new(n);
        for seed in 0..3u64 {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.mul(&xtrue);
            let stats = pcg_into(&a, &b, &IdentityPrecond, 1e-10, 500, &mut ws);
            assert!(stats.converged, "seed {seed}: {}", stats.residual_norm);
            for (x, t) in ws.v.iter().zip(&xtrue) {
                assert!((x - t).abs() < 1e-7, "seed {seed}");
            }
            // Scratch state from the previous solve must not leak in:
            // result equals the fresh-scratch wrapper's.
            let fresh = pcg(&a, &b, &IdentityPrecond, 1e-10, 500);
            assert_eq!(ws.v, fresh.v);
            assert_eq!(ws.hv, fresh.hv);
        }
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let a = spd(10, 4, 0.5);
        let res = pcg(&a, &vec![0.0; 10], &IdentityPrecond, 1e-12, 10);
        assert_eq!(res.iterations, 0);
        assert!(res.converged);
        assert_eq!(res.v, vec![0.0; 10]);
    }
}
