//! Single-machine (preconditioned) conjugate gradients.
//!
//! This is the *reference* PCG used to validate the distributed
//! implementations (Algorithms 2 and 3 produce, in exact arithmetic, the
//! same iterates as this solver applied to the aggregated system) and by
//! the single-node reference Newton solver.

use crate::linalg::ops;

/// Abstract SPD operator `y = A x`.
pub trait LinearOperator {
    fn dim(&self) -> usize;
    fn apply_into(&self, x: &[f64], y: &mut [f64]);

    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim()];
        self.apply_into(x, &mut y);
        y
    }
}

/// Abstract preconditioner apply `y = M⁻¹ x`.
pub trait Preconditioner {
    fn apply_into(&self, x: &[f64], y: &mut [f64]);
}

/// Identity preconditioner (plain CG).
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(x);
    }
}

impl Preconditioner for crate::solvers::woodbury::Woodbury {
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        crate::solvers::woodbury::Woodbury::apply_into(self, x, y)
    }
}

/// Dense matrix as operator (tests).
impl LinearOperator for crate::linalg::SquareMatrix {
    fn dim(&self) -> usize {
        self.n()
    }
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        self.mul_into(x, y)
    }
}

/// Outcome of a PCG solve.
#[derive(Clone, Debug)]
pub struct PcgResult {
    pub v: Vec<f64>,
    /// `H v` at the solution (needed for the Newton decrement δ).
    pub hv: Vec<f64>,
    pub iterations: usize,
    pub residual_norm: f64,
    pub converged: bool,
}

/// Solve `A v = b` to `‖r‖ ≤ tol`, at most `max_iter` steps, with
/// preconditioner `M⁻¹`. Follows the paper's Algorithm 2 update order
/// (tracks `Hv` incrementally, line 6).
pub fn pcg(
    a: &impl LinearOperator,
    b: &[f64],
    m_inv: &impl Preconditioner,
    tol: f64,
    max_iter: usize,
) -> PcgResult {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let mut v = vec![0.0; n];
    let mut hv = vec![0.0; n];
    let mut r = b.to_vec(); // r_0 = b − A·0
    let mut s = vec![0.0; n];
    m_inv.apply_into(&r, &mut s);
    let mut u = s.clone();
    let mut hu = vec![0.0; n];
    let mut rs = ops::dot(&r, &s);
    let mut iterations = 0;
    let mut rnorm = ops::norm2(&r);

    while rnorm > tol && iterations < max_iter {
        a.apply_into(&u, &mut hu);
        let uhu = ops::dot(&u, &hu);
        if uhu <= 0.0 {
            // Operator not PD along u (numerical breakdown) — bail with
            // the current iterate rather than diverging.
            break;
        }
        let alpha = rs / uhu;
        ops::axpy(alpha, &u, &mut v);
        ops::axpy(alpha, &hu, &mut hv);
        ops::axpy(-alpha, &hu, &mut r);
        m_inv.apply_into(&r, &mut s);
        let rs_new = ops::dot(&r, &s);
        let beta = rs_new / rs;
        rs = rs_new;
        ops::axpby(1.0, &s, beta, &mut u);
        rnorm = ops::norm2(&r);
        iterations += 1;
    }
    PcgResult {
        v,
        hv,
        iterations,
        residual_norm: rnorm,
        converged: rnorm <= tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SquareMatrix;
    use crate::solvers::woodbury::Woodbury;
    use crate::util::prng::Xoshiro256pp;

    fn spd(n: usize, seed: u64, cond_boost: f64) -> SquareMatrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = SquareMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a.set(i, j, s / n as f64 + if i == j { cond_boost } else { 0.0 });
            }
        }
        a
    }

    #[test]
    fn cg_solves_spd_system() {
        let n = 30;
        let a = spd(n, 1, 0.5);
        let xtrue: Vec<f64> = (0..n).map(|i| (i as f64 * 0.2).sin()).collect();
        let b = a.mul(&xtrue);
        let res = pcg(&a, &b, &IdentityPrecond, 1e-10, 500);
        assert!(res.converged, "residual {}", res.residual_norm);
        for (x, t) in res.v.iter().zip(&xtrue) {
            assert!((x - t).abs() < 1e-7);
        }
        // hv tracked incrementally must equal A·v.
        let av = a.mul(&res.v);
        for (h, t) in res.hv.iter().zip(&av) {
            assert!((h - t).abs() < 1e-7);
        }
    }

    #[test]
    fn exact_preconditioner_converges_in_one_iteration() {
        // If M = A exactly, PCG must converge in a single step.
        let n = 12;
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let cols: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let w = vec![0.7; 6];
        let wb = Woodbury::new(n, &cols, &w, 0.4).unwrap();
        let a = wb.dense(); // operator IS the preconditioner
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let res = pcg(&a, &b, &wb, 1e-9, 50);
        assert!(res.converged);
        assert_eq!(res.iterations, 1, "exact preconditioning must take 1 step");
    }

    #[test]
    fn good_preconditioner_beats_plain_cg() {
        // A = P + small perturbation ⇒ PCG(P) needs far fewer iterations.
        let n = 40;
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let cols: Vec<Vec<f64>> = (0..20)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let w = vec![0.5; 20];
        let wb = Woodbury::new(n, &cols, &w, 0.05).unwrap();
        let mut a = wb.dense();
        for i in 0..n {
            a.add_to(i, i, 0.01 * (1.0 + (i as f64 * 0.4).sin().abs()));
        }
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let plain = pcg(&a, &b, &IdentityPrecond, 1e-8, 2000);
        let pre = pcg(&a, &b, &wb, 1e-8, 2000);
        assert!(pre.converged && plain.converged);
        assert!(
            pre.iterations * 2 <= plain.iterations,
            "PCG {} vs CG {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn max_iter_respected() {
        let a = spd(25, 3, 0.01);
        let b = vec![1.0; 25];
        let res = pcg(&a, &b, &IdentityPrecond, 1e-16, 3);
        assert_eq!(res.iterations, 3);
        assert!(!res.converged);
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let a = spd(10, 4, 0.5);
        let res = pcg(&a, &vec![0.0; 10], &IdentityPrecond, 1e-12, 10);
        assert_eq!(res.iterations, 0);
        assert!(res.converged);
        assert_eq!(res.v, vec![0.0; 10]);
    }
}
