//! Single-machine inexact Newton reference solver.
//!
//! Produces ground-truth optima `(w*, f*)` for tests and for the
//! suboptimality axis of the experiment harness. It is exactly the damped
//! Newton outer loop of the paper (Algorithm 1) with a *plain CG* inner
//! solve on one machine — no preconditioning games, no distribution — so
//! distributed runs can be validated against it.

use crate::linalg::{ops, HvpKernel};
use crate::loss::Objective;
use crate::solvers::pcg::{pcg_into, IdentityPrecond, LinearOperator, PcgScratch};

/// Hessian operator at a fixed point (scalings precomputed, fused hybrid
/// kernel shared across outer iterations).
struct HessOp<'a> {
    obj: &'a Objective<'a>,
    kernel: &'a HvpKernel,
    s: Vec<f64>,
    scratch: std::cell::RefCell<Vec<f64>>,
}

impl<'a> LinearOperator for HessOp<'a> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }
    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        let mut scratch = self.scratch.borrow_mut();
        self.obj
            .hvp_with_kernel_into(self.kernel, &self.s, x, &mut scratch, y);
    }
}

#[derive(Clone, Debug)]
pub struct NewtonResult {
    pub w: Vec<f64>,
    pub fval: f64,
    pub grad_norm: f64,
    pub outer_iterations: usize,
    pub total_cg_iterations: usize,
    pub converged: bool,
}

/// Minimize `obj` to `‖∇f‖ ≤ grad_tol`.
pub fn newton_reference(
    obj: &Objective,
    grad_tol: f64,
    max_outer: usize,
    max_cg: usize,
) -> NewtonResult {
    let d = obj.dim();
    let mut w = vec![0.0; d];
    let mut total_cg = 0;
    // Fused hybrid kernel + PCG scratch: built once, reused by every
    // inner solve — no allocation inside the CG loop.
    let kernel = obj.hvp_kernel();
    let mut ws = PcgScratch::new(d);
    for outer in 0..max_outer {
        let g = obj.grad(&w);
        let gnorm = ops::norm2(&g);
        if gnorm <= grad_tol {
            return NewtonResult {
                fval: obj.value(&w),
                w,
                grad_norm: gnorm,
                outer_iterations: outer,
                total_cg_iterations: total_cg,
                converged: true,
            };
        }
        let op = HessOp {
            obj,
            kernel: &kernel,
            s: obj.hessian_scalings(&w),
            scratch: std::cell::RefCell::new(vec![0.0; obj.nsamples()]),
        };
        // Zhang–Xiao style forcing term: ε_k = min(0.25, ‖g‖)·‖g‖/20.
        let eps = (gnorm / 20.0).min(0.25 * gnorm).max(grad_tol * 0.1);
        let stats = pcg_into(&op, &g, &IdentityPrecond, eps, max_cg, &mut ws);
        total_cg += stats.iterations;
        // Damped step: δ = √(vᵀHv).
        let delta = ops::dot(&ws.v, &ws.hv).max(0.0).sqrt();
        let scale = 1.0 / (1.0 + delta);
        ops::axpy(-scale, &ws.v, &mut w);
    }
    let g = obj.grad(&w);
    NewtonResult {
        fval: obj.value(&w),
        grad_norm: ops::norm2(&g),
        w,
        outer_iterations: max_outer,
        total_cg_iterations: total_cg,
        converged: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, DataMatrix};
    use crate::loss::{Logistic, Quadratic, SquaredHinge};
    use crate::util::prng::Xoshiro256pp;

    fn make(seed: u64, d: usize, n: usize) -> (DataMatrix, Vec<f64>) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let x = DataMatrix::Sparse(CscMatrix::rand_sparse(d, n, 0.3, &mut rng));
        let y = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn converges_on_all_losses() {
        let (x, y) = make(1, 20, 80);
        for loss in [
            &Quadratic as &dyn crate::loss::Loss,
            &Logistic,
            &SquaredHinge,
        ] {
            let obj = Objective::new(&x, &y, loss, 1e-2);
            let res = newton_reference(&obj, 1e-9, 50, 500);
            assert!(res.converged, "{} gnorm={}", loss.name(), res.grad_norm);
            assert!(res.grad_norm <= 1e-9);
        }
    }

    #[test]
    fn quadratic_loss_is_one_newton_step() {
        // With quadratic loss f is quadratic: a single (well-solved) Newton
        // step plus damping must reach tiny gradients in very few iters.
        let (x, y) = make(2, 10, 50);
        let obj = Objective::new(&x, &y, &Quadratic, 0.1);
        let res = newton_reference(&obj, 1e-8, 30, 1000);
        assert!(res.converged);
        assert!(
            res.outer_iterations <= 12,
            "took {} outer iterations",
            res.outer_iterations
        );
    }

    #[test]
    fn optimum_is_stationary_under_perturbation() {
        let (x, y) = make(3, 8, 40);
        let obj = Objective::new(&x, &y, &Logistic, 0.05);
        let res = newton_reference(&obj, 1e-10, 60, 800);
        assert!(res.converged);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        for _ in 0..5 {
            let mut wp = res.w.clone();
            for v in wp.iter_mut() {
                *v += 1e-3 * rng.normal();
            }
            assert!(
                obj.value(&wp) >= res.fval - 1e-12,
                "perturbed value below optimum"
            );
        }
    }
}
