//! Stochastic Average Gradient (Schmidt, Le Roux & Bach 2013).
//!
//! Two roles in this repo, both *baseline-side*:
//!
//! 1. The **original DiSCO**'s preconditioner solve: Zhang & Xiao suggest
//!    solving `P s = r` with an iterative linear-rate method run **on the
//!    master only** — the serial bottleneck the paper's §1.2 measures at
//!    >50 % of runtime. [`solve_linear_system`] reproduces that path.
//! 2. The **DANE** local subproblem (paper Eq. (1)), a generic smooth
//!    strongly-convex ERM solved per node: [`SagSolver`].
//!
//! The implementation follows SAG's standard form: a per-sample gradient
//! table for the data term, with deterministic affine parts (ℓ2 terms,
//! linear shifts) applied exactly each step.

use crate::linalg::{ops, DataMatrix};
use crate::util::prng::Xoshiro256pp;

/// Generic SAG over `min_w (1/n) Σ ℓ_j(x_jᵀ w) + (κ/2)‖w‖² + cᵀw`.
///
/// `scalar_deriv(j, z)` returns `ℓ_j'(z)`; `lmax` bounds `ℓ_j''·‖x_j‖²`
/// for the step size.
pub struct SagSolver<'a> {
    pub x: &'a DataMatrix,
    pub kappa: f64,
    pub linear: &'a [f64],
    /// Upper bound on per-sample curvature (sets the 1/L step).
    pub lmax: f64,
}

impl<'a> SagSolver<'a> {
    /// Run `epochs · n` stochastic steps from `w0`. Returns the iterate.
    pub fn run(
        &self,
        scalar_deriv: impl Fn(usize, f64) -> f64,
        w0: &[f64],
        epochs: usize,
        rng: &mut Xoshiro256pp,
    ) -> Vec<f64> {
        let d = self.x.nrows();
        let n = self.x.ncols();
        assert_eq!(w0.len(), d);
        assert_eq!(self.linear.len(), d);
        let mut w = w0.to_vec();
        // Gradient table: per-sample scalar g_j = ℓ_j'(x_jᵀw at last visit);
        // data-term average gradient = (1/n) Σ g_j x_j kept as dense `avg`.
        let mut table = vec![0.0; n];
        let mut avg = vec![0.0; d];
        let step = 1.0 / (self.lmax + self.kappa).max(1e-12);
        for _ in 0..epochs * n {
            let j = rng.index(n);
            let z = self.x.col_dot(j, &w);
            let g_new = scalar_deriv(j, z);
            let delta = g_new - table[j];
            table[j] = g_new;
            // avg += delta/n · x_j
            self.x.col_axpy(j, delta / n as f64, &mut avg);
            // w ← w − step·(avg + κw + c)
            for i in 0..d {
                w[i] -= step * (avg[i] + self.kappa * w[i] + self.linear[i]);
            }
        }
        w
    }
}

/// Solve the SPD system `P s = r` with `P = dreg·I + Σ_i (c_i/τ)·x_i x_iᵀ`
/// by SAG on the quadratic `min_s ½ sᵀPs − rᵀs` — the original-DiSCO
/// master-only preconditioner path. `columns` are the τ preconditioner
/// samples (dense), `weights[i] = c_i/τ` their full coefficients.
///
/// Returns `(s, passes)` where `passes` counts epoch-equivalents executed
/// (the serial work the master performs while workers idle).
pub fn solve_linear_system(
    columns: &[Vec<f64>],
    weights: &[f64],
    dreg: f64,
    r: &[f64],
    tol: f64,
    max_epochs: usize,
    rng: &mut Xoshiro256pp,
) -> (Vec<f64>, usize) {
    let d = r.len();
    let tau = columns.len();
    assert_eq!(weights.len(), tau);
    let mut s = vec![0.0; d];
    if tau == 0 {
        for (si, ri) in s.iter_mut().zip(r.iter()) {
            *si = ri / dreg;
        }
        return (s, 0);
    }
    // Quadratic per-sample loss: ℓ_i(z) = (τ·w_i)/2 · z² over x_i ⇒
    // full objective (1/τ)Σ ℓ_i(x_iᵀs) = ½ sᵀ(Σ w_i x_i x_iᵀ)s.
    let lmax = columns
        .iter()
        .zip(weights.iter())
        .map(|(c, w)| w * tau as f64 * ops::norm2_sq(c))
        .fold(0.0, f64::max);
    let step = 1.0 / (lmax + dreg).max(1e-12);

    let mut table = vec![0.0; tau];
    let mut avg = vec![0.0; d];
    let mut linear_resid = vec![0.0; d]; // current full gradient estimate
    let mut passes = 0usize;
    for epoch in 0..max_epochs {
        for _ in 0..tau {
            let j = rng.index(tau);
            let z = ops::dot(&columns[j], &s);
            let g_new = weights[j] * tau as f64 * z;
            let delta = g_new - table[j];
            table[j] = g_new;
            ops::axpy(delta / tau as f64, &columns[j], &mut avg);
            for i in 0..d {
                s[i] -= step * (avg[i] + dreg * s[i] - r[i]);
            }
        }
        passes = epoch + 1;
        // Convergence check on the true residual ‖Ps − r‖ (O(dτ)).
        for i in 0..d {
            linear_resid[i] = dreg * s[i] - r[i];
        }
        for (c, w) in columns.iter().zip(weights.iter()) {
            let z = ops::dot(c, &s);
            ops::axpy(w * z, c, &mut linear_resid);
        }
        if ops::norm2(&linear_resid) <= tol {
            break;
        }
    }
    (s, passes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, SquareMatrix};
    use crate::linalg::lu_solve;

    #[test]
    fn linear_system_matches_direct_solve() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let d = 12;
        let tau = 8;
        let columns: Vec<Vec<f64>> = (0..tau)
            .map(|_| (0..d).map(|_| rng.normal() * 0.5).collect())
            .collect();
        let weights: Vec<f64> = (0..tau).map(|_| rng.uniform(0.05, 0.3)).collect();
        let dreg = 0.5;
        let r: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        // Dense P for the reference solve.
        let mut p = SquareMatrix::zeros(d);
        for i in 0..d {
            p.set(i, i, dreg);
        }
        for (c, w) in columns.iter().zip(&weights) {
            for i in 0..d {
                for j in 0..d {
                    p.add_to(i, j, w * c[i] * c[j]);
                }
            }
        }
        let direct = lu_solve(&p, &r).unwrap();
        let (s, passes) = solve_linear_system(&columns, &weights, dreg, &r, 1e-9, 8000, &mut rng);
        assert!(passes > 0);
        for (a, b) in s.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b} after {passes} passes");
        }
    }

    #[test]
    fn empty_system_is_diagonal_solve() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let (s, passes) = solve_linear_system(&[], &[], 2.0, &[4.0, 8.0], 1e-12, 10, &mut rng);
        assert_eq!(s, vec![2.0, 4.0]);
        assert_eq!(passes, 0);
    }

    #[test]
    fn sag_solver_minimizes_ridge_regression() {
        // min (1/n) Σ ½(x_jᵀw − y_j)² + (κ/2)‖w‖² — compare to normal eqs.
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let d = 6;
        let n = 40;
        let x = DataMatrix::Sparse(CscMatrix::rand_sparse(d, n, 0.6, &mut rng));
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let kappa = 0.3;
        // Normal equations: ((1/n)XXᵀ + κI) w = (1/n)X y.
        let xd = x.to_dense();
        let mut a = SquareMatrix::zeros(d);
        for i in 0..d {
            a.set(i, i, kappa);
        }
        for j in 0..n {
            let c = xd.col(j);
            for ii in 0..d {
                for jj in 0..d {
                    a.add_to(ii, jj, c[ii] * c[jj] / n as f64);
                }
            }
        }
        let rhs = {
            let mut v = x.a_mul(&y);
            ops::scale(1.0 / n as f64, &mut v);
            v
        };
        let wref = lu_solve(&a, &rhs).unwrap();

        let lmax = (0..n).map(|j| x.col_norm_sq(j)).fold(0.0, f64::max);
        let linear = vec![0.0; d];
        let solver = SagSolver {
            x: &x,
            kappa,
            linear: &linear,
            lmax,
        };
        let w = solver.run(
            |j, z| z - y[j],
            &vec![0.0; d],
            400,
            &mut rng,
        );
        for (a, b) in w.iter().zip(&wref) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}
