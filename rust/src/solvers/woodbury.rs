//! Closed-form preconditioner solve via the Woodbury identity — the
//! paper's Algorithm 4 and first contribution (§1.2, §4).
//!
//! The stochastic preconditioner (paper Eq. 5/8/9) is
//!
//! ```text
//! P = D + Σ_{i=1..τ} w_i x_i x_iᵀ,   D = (λ+μ)I,
//! ```
//!
//! with `w_i = φ''(wᵀx_i; y_i)/τ` (the caller passes exact coefficients).
//! Writing `Ũ = [√w_1·x_1, …]`, `P = D + ŨŨᵀ` and
//!
//! ```text
//! P⁻¹r = D⁻¹r − D⁻¹Ũ (I + ŨᵀD⁻¹Ũ)⁻¹ ŨᵀD⁻¹r.
//! ```
//!
//! **Factorization split (§Perf):** the τ×τ inner matrix is
//! `K = I + (1/dreg)·D_w^{½} G D_w^{½}` where `G = XᵀX` is the *raw* Gram
//! of the τ sample columns — constant across outer Newton iterations.
//! [`WoodburyFactory`] computes `G` once (O(τ²d)); each outer iteration's
//! [`WoodburyFactory::build`] merely rescales entries and refactors
//! (O(τ² + τ³/3)), and each PCG step's [`Woodbury::apply_into`] is two
//! skinny GEMVs plus triangular solves (O(dτ)). This replaces the
//! original DiSCO's per-step iterative SAG solve (see
//! `algorithms::disco_s::Precond::MasterSag`).

use crate::linalg::dense::SquareMatrix;
use crate::linalg::{ops, Cholesky};

#[derive(Debug)]
pub enum WoodburyError {
    /// Inner τ×τ system not PD (cannot happen with dreg > 0 and finite
    /// data; kept for defensive reporting).
    Factorization(String),
    /// dreg must be positive for D to be invertible.
    BadRegularization(f64),
}

impl std::fmt::Display for WoodburyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WoodburyError::Factorization(e) => write!(f, "woodbury inner factorization: {e}"),
            WoodburyError::BadRegularization(d) => write!(f, "woodbury needs dreg > 0, got {d}"),
        }
    }
}
impl std::error::Error for WoodburyError {}

/// Reusable part: the τ columns and their raw Gram `G = XᵀX`.
pub struct WoodburyFactory {
    dim: usize,
    k: usize,
    /// Raw columns, flattened (column i at `cols[i*dim..(i+1)*dim]`).
    cols: Vec<f64>,
    raw_gram: SquareMatrix,
}

impl WoodburyFactory {
    /// Compute the raw Gram once. O(τ²·d/2).
    pub fn new(dim: usize, columns: &[Vec<f64>]) -> Self {
        let k = columns.len();
        let mut cols = Vec::with_capacity(k * dim);
        for c in columns {
            assert_eq!(c.len(), dim, "column length mismatch");
            cols.extend_from_slice(c);
        }
        let mut raw_gram = SquareMatrix::zeros(k);
        for i in 0..k {
            let ci = &cols[i * dim..(i + 1) * dim];
            for j in 0..=i {
                let cj = &cols[j * dim..(j + 1) * dim];
                let g = ops::dot(ci, cj);
                raw_gram.set(i, j, g);
                if i != j {
                    raw_gram.set(j, i, g);
                }
            }
        }
        Self {
            dim,
            k,
            cols,
            raw_gram,
        }
    }

    pub fn rank(&self) -> usize {
        self.k
    }

    /// Factor the preconditioner for the given per-column weights
    /// (`weights[i] ≥ 0`; zero-weight columns contribute nothing).
    /// O(τ² + τ³/3) — independent of d.
    pub fn build(&self, weights: &[f64], dreg: f64) -> Result<Woodbury, WoodburyError> {
        assert_eq!(weights.len(), self.k);
        if dreg <= 0.0 {
            return Err(WoodburyError::BadRegularization(dreg));
        }
        let sqrtw: Vec<f64> = weights.iter().map(|w| w.max(0.0).sqrt()).collect();
        let chol = if self.k > 0 {
            let mut kmat = SquareMatrix::zeros(self.k);
            let inv_d = 1.0 / dreg;
            for i in 0..self.k {
                for j in 0..=i {
                    let v = sqrtw[i] * sqrtw[j] * self.raw_gram.get(i, j) * inv_d
                        + if i == j { 1.0 } else { 0.0 };
                    kmat.set(i, j, v);
                    if i != j {
                        kmat.set(j, i, v);
                    }
                }
            }
            Some(
                Cholesky::factor(&kmat)
                    .map_err(|e| WoodburyError::Factorization(e.to_string()))?,
            )
        } else {
            None
        };
        Ok(Woodbury {
            dim: self.dim,
            dreg,
            cols: self.cols.clone(),
            sqrtw,
            k: self.k,
            chol,
            scratch_k: std::cell::RefCell::new(vec![0.0; self.k]),
        })
    }
}

/// Factored preconditioner `P = dreg·I + Σ_i w_i · x_i x_iᵀ`.
pub struct Woodbury {
    dim: usize,
    dreg: f64,
    /// Raw columns, flattened.
    cols: Vec<f64>,
    /// √w_i per column (0 for inactive columns).
    sqrtw: Vec<f64>,
    k: usize,
    chol: Option<Cholesky>,
    scratch_k: std::cell::RefCell<Vec<f64>>,
}

impl Woodbury {
    /// One-shot construction (convenience; prefer [`WoodburyFactory`] when
    /// rebuilding with new weights every outer iteration).
    pub fn new(
        dim: usize,
        columns: &[Vec<f64>],
        weights: &[f64],
        dreg: f64,
    ) -> Result<Self, WoodburyError> {
        assert_eq!(columns.len(), weights.len());
        WoodburyFactory::new(dim, columns).build(weights, dreg)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of active (positive-weight) rank-1 terms.
    pub fn rank(&self) -> usize {
        self.sqrtw.iter().filter(|w| **w > 1e-7).count()
    }

    /// `out ← P⁻¹ r`. O(d·k) plus a k×k triangular solve.
    pub fn apply_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.dim);
        assert_eq!(out.len(), self.dim);
        let inv_d = 1.0 / self.dreg;
        if self.k == 0 {
            for (o, ri) in out.iter_mut().zip(r.iter()) {
                *o = ri * inv_d;
            }
            return;
        }
        // t = Ũᵀ (D⁻¹ r), with Ũ_i = √w_i·x_i.
        let mut t = self.scratch_k.borrow_mut();
        for i in 0..self.k {
            t[i] = if self.sqrtw[i] > 0.0 {
                self.sqrtw[i] * ops::dot(&self.cols[i * self.dim..(i + 1) * self.dim], r) * inv_d
            } else {
                0.0
            };
        }
        // v = K⁻¹ t
        let v = self.chol.as_ref().unwrap().solve(&t);
        // out = D⁻¹ r − D⁻¹ Ũ v
        for (o, ri) in out.iter_mut().zip(r.iter()) {
            *o = ri * inv_d;
        }
        for i in 0..self.k {
            let c = self.sqrtw[i] * v[i] * inv_d;
            if c != 0.0 {
                ops::axpy(-c, &self.cols[i * self.dim..(i + 1) * self.dim], out);
            }
        }
    }

    pub fn apply(&self, r: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.apply_into(r, &mut out);
        out
    }

    /// Dense `P` (tests only).
    pub fn dense(&self) -> SquareMatrix {
        let mut p = SquareMatrix::zeros(self.dim);
        for i in 0..self.dim {
            p.set(i, i, self.dreg);
        }
        for t in 0..self.k {
            let c = &self.cols[t * self.dim..(t + 1) * self.dim];
            let w = self.sqrtw[t] * self.sqrtw[t];
            for i in 0..self.dim {
                for j in 0..self.dim {
                    p.add_to(i, j, w * c[i] * c[j]);
                }
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::lu_solve;
    use crate::util::prng::Xoshiro256pp;

    fn random_cols(d: usize, k: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let cols = (0..k)
            .map(|_| (0..d).map(|_| rng.normal()).collect::<Vec<f64>>())
            .collect();
        let weights = (0..k).map(|_| rng.uniform(0.05, 2.0)).collect();
        (cols, weights)
    }

    #[test]
    fn apply_matches_direct_inverse() {
        for (d, k) in [(6, 0), (6, 1), (10, 4), (20, 7), (8, 8), (5, 9)] {
            let (cols, w) = random_cols(d, k, (d * 100 + k) as u64);
            let wb = Woodbury::new(d, &cols, &w, 0.3).unwrap();
            let p = wb.dense();
            let mut rng = Xoshiro256pp::seed_from_u64(1);
            let r: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let direct = lu_solve(&p, &r).unwrap();
            let fast = wb.apply(&r);
            for (a, b) in fast.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-9, "d={d},k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn factory_reuse_matches_oneshot() {
        // Rebuilding with different weights from one factory must equal
        // the from-scratch construction (the §Perf path's correctness).
        let (cols, w1) = random_cols(12, 9, 42);
        let factory = WoodburyFactory::new(12, &cols);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for scale in [1.0, 0.3, 7.0] {
            let w: Vec<f64> = w1.iter().map(|v| v * scale).collect();
            let fast = factory.build(&w, 0.2).unwrap().apply(&r);
            let slow = Woodbury::new(12, &cols, &w, 0.2).unwrap().apply(&r);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn zero_weight_columns_inactive() {
        let (cols, _) = random_cols(8, 3, 3);
        let wb = Woodbury::new(8, &cols, &[0.5, 0.0, 1.0], 0.2).unwrap();
        assert_eq!(wb.rank(), 2);
        // Exactness with a zero weight: compare to direct inverse.
        let p = wb.dense();
        let r = vec![1.0; 8];
        let direct = lu_solve(&p, &r).unwrap();
        for (a, b) in wb.apply(&r).iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn no_columns_is_scaled_identity() {
        let wb = Woodbury::new(4, &[], &[], 2.0).unwrap();
        let out = wb.apply(&[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn solve_is_exact_preconditioner_identity() {
        // P · (P⁻¹ r) = r
        let (cols, w) = random_cols(12, 5, 7);
        let wb = Woodbury::new(12, &cols, &w, 0.15).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let s = wb.apply(&r);
        let back = wb.dense().mul(&s);
        for (a, b) in back.iter().zip(&r) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_nonpositive_dreg() {
        let (cols, w) = random_cols(4, 2, 5);
        assert!(Woodbury::new(4, &cols, &w, 0.0).is_err());
        assert!(Woodbury::new(4, &cols, &w, -1.0).is_err());
    }

    #[test]
    fn tau_exceeding_dim_still_exact() {
        // k > d exercises the "wide" regime where Woodbury's τ×τ system is
        // larger than d — still exact, just not the fast case.
        let (cols, w) = random_cols(4, 12, 6);
        let wb = Woodbury::new(4, &cols, &w, 0.5).unwrap();
        let p = wb.dense();
        let r = vec![1.0, -1.0, 2.0, 0.5];
        let direct = lu_solve(&p, &r).unwrap();
        for (a, b) in wb.apply(&r).iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
