//! Numerical solvers: the Woodbury closed-form preconditioner (paper
//! Alg. 4), reference PCG, SAG (original-DiSCO preconditioner path and
//! DANE local solver), SDCA (CoCoA+ local solver), and the single-machine
//! Newton reference used as ground truth.

pub mod newton_ref;
pub mod pcg;
pub mod sag;
pub mod sdca;
pub mod woodbury;

pub use newton_ref::{newton_reference, NewtonResult};
pub use pcg::{
    pcg, pcg_into, IdentityPrecond, LinearOperator, PcgResult, PcgScratch, PcgStats, Preconditioner,
};
pub use sdca::SdcaLocal;
pub use woodbury::Woodbury;
