//! Loss functions for the regularized ERM objective (paper Eq. (P)) and
//! their duals (Eq. (D)) used by the CoCoA+/SDCA baseline.
//!
//! Every loss is a scalar function `φ(z; y)` of the margin `z = wᵀx` and
//! label `y`, exposing value / first / second derivative (for gradients and
//! Hessian-vector products), the self-concordance constant `M` from the
//! paper's Table 1, the convex conjugate `φ*` (dual objective), and the
//! SDCA single-coordinate maximizer.

pub mod logistic;
pub mod objective;
pub mod quadratic;
pub mod squared_hinge;

pub use logistic::Logistic;
pub use objective::Objective;
pub use quadratic::Quadratic;
pub use squared_hinge::SquaredHinge;

/// Scalar loss interface. Implementations must be pure and cheap — these
/// are called once per (sample × PCG step) on the native path.
pub trait Loss: Send + Sync {
    fn name(&self) -> &'static str;

    /// `φ(z; y)`.
    fn value(&self, z: f64, y: f64) -> f64;

    /// `∂φ/∂z`.
    fn deriv(&self, z: f64, y: f64) -> f64;

    /// `∂²φ/∂z²` — the per-sample Hessian scaling `s_i` in
    /// `f''(w) = (1/n) X diag(s) Xᵀ + λI`.
    fn second_deriv(&self, z: f64, y: f64) -> f64;

    /// Smoothness constant: `sup φ'' ` (paper Assumption 2's `L` up to the
    /// data norm factor).
    fn smoothness(&self) -> f64;

    /// Self-concordance parameter `M` (paper Table 1).
    fn self_concordance_m(&self) -> f64;

    /// True when `φ''` does not depend on the margin (quadratic loss) —
    /// lets the coordinator build the Woodbury preconditioner once instead
    /// of once per outer iteration (§Perf optimization).
    fn curvature_is_constant(&self) -> bool {
        false
    }

    /// Convex conjugate `φ*(u; y) = sup_z (u·z − φ(z; y))`. Returns
    /// `f64::INFINITY` outside the conjugate's domain.
    fn conjugate(&self, u: f64, y: f64) -> f64;

    /// SDCA coordinate step: given label `y`, current margin `z = wᵀx_i`,
    /// current dual variable `α_i`, and curvature `q = ‖x_i‖²/(λn)`,
    /// return `Δα` maximizing the dual increment
    /// `−φ*(−(α_i+Δ)) − Δ·z − q·Δ²/2` (see DESIGN.md §6 / Shalev-Shwartz &
    /// Zhang 2013).
    fn sdca_delta(&self, y: f64, z: f64, alpha: f64, q: f64) -> f64;
}

/// Loss selection by name (CLI / config).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LossKind {
    Quadratic,
    Logistic,
    SquaredHinge,
}

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        match s.to_ascii_lowercase().as_str() {
            "quadratic" | "square" | "squared" | "ls" => Some(LossKind::Quadratic),
            "logistic" | "logreg" | "log" => Some(LossKind::Logistic),
            "squared_hinge" | "squared-hinge" | "l2svm" => Some(LossKind::SquaredHinge),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Quadratic => "quadratic",
            LossKind::Logistic => "logistic",
            LossKind::SquaredHinge => "squared_hinge",
        }
    }

    pub fn make(&self) -> Box<dyn Loss> {
        match self {
            LossKind::Quadratic => Box::new(Quadratic),
            LossKind::Logistic => Box::new(Logistic),
            LossKind::SquaredHinge => Box::new(SquaredHinge),
        }
    }
}

/// Finite-difference checks shared by per-loss unit tests.
#[cfg(test)]
pub(crate) mod checks {
    use super::Loss;

    pub fn grad_matches_fd(loss: &dyn Loss, zs: &[f64], ys: &[f64]) {
        let h = 1e-6;
        for &y in ys {
            for &z in zs {
                let fd = (loss.value(z + h, y) - loss.value(z - h, y)) / (2.0 * h);
                let an = loss.deriv(z, y);
                assert!(
                    (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                    "{}: dφ/dz at z={z}, y={y}: fd={fd} vs {an}",
                    loss.name()
                );
            }
        }
    }

    pub fn hess_matches_fd(loss: &dyn Loss, zs: &[f64], ys: &[f64]) {
        let h = 1e-5;
        for &y in ys {
            for &z in zs {
                let fd = (loss.deriv(z + h, y) - loss.deriv(z - h, y)) / (2.0 * h);
                let an = loss.second_deriv(z, y);
                assert!(
                    (fd - an).abs() < 1e-4 * (1.0 + an.abs()),
                    "{}: d²φ/dz² at z={z}, y={y}: fd={fd} vs {an}",
                    loss.name()
                );
            }
        }
    }

    /// Fenchel–Young: φ(z) + φ*(u) ≥ u·z, equality at u = φ'(z).
    pub fn fenchel_young(loss: &dyn Loss, zs: &[f64], ys: &[f64]) {
        for &y in ys {
            for &z in zs {
                let u = loss.deriv(z, y);
                let lhs = loss.value(z, y) + loss.conjugate(u, y);
                assert!(
                    (lhs - u * z).abs() < 1e-6 * (1.0 + lhs.abs()),
                    "{}: Fenchel equality at z={z}, y={y}: {lhs} vs {}",
                    loss.name(),
                    u * z
                );
                // Inequality at a few other u values.
                for du in [-0.3, 0.2] {
                    let u2 = u + du;
                    let c = loss.conjugate(u2, y);
                    if c.is_finite() {
                        assert!(
                            loss.value(z, y) + c >= u2 * z - 1e-9,
                            "{}: Fenchel-Young violated at z={z}, u={u2}, y={y}",
                            loss.name()
                        );
                    }
                }
            }
        }
    }
}
