//! Quadratic loss `φ(z; y) = (z − y)²` (paper Table 1, M = 0).
//!
//! The paper writes `(y_i − wᵀx_i)²`, identical by symmetry. Its Hessian
//! scaling is the constant 2, so `f''(w)` is independent of `w` — the case
//! the paper uses to present Algorithm 2.

use super::Loss;

#[derive(Clone, Copy, Debug, Default)]
pub struct Quadratic;

impl Loss for Quadratic {
    fn name(&self) -> &'static str {
        "quadratic"
    }

    #[inline]
    fn value(&self, z: f64, y: f64) -> f64 {
        let r = z - y;
        r * r
    }

    #[inline]
    fn deriv(&self, z: f64, y: f64) -> f64 {
        2.0 * (z - y)
    }

    #[inline]
    fn second_deriv(&self, _z: f64, _y: f64) -> f64 {
        2.0
    }

    fn smoothness(&self) -> f64 {
        2.0
    }

    fn self_concordance_m(&self) -> f64 {
        0.0
    }

    fn curvature_is_constant(&self) -> bool {
        true
    }

    /// `φ*(u; y) = u·y + u²/4`.
    #[inline]
    fn conjugate(&self, u: f64, y: f64) -> f64 {
        u * y + u * u / 4.0
    }

    /// Closed form: maximize `(α+Δ)y − (α+Δ)²/4 − Δz − qΔ²/2`
    /// ⇒ `Δ = (y − z − α/2) / (1/2 + q)`.
    #[inline]
    fn sdca_delta(&self, y: f64, z: f64, alpha: f64, q: f64) -> f64 {
        (y - z - alpha / 2.0) / (0.5 + q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::checks;

    const ZS: &[f64] = &[-3.0, -0.7, 0.0, 0.4, 2.5];
    const YS: &[f64] = &[-1.0, 1.0, 0.3];

    #[test]
    fn derivatives_match_finite_differences() {
        checks::grad_matches_fd(&Quadratic, ZS, YS);
        checks::hess_matches_fd(&Quadratic, ZS, YS);
    }

    #[test]
    fn fenchel_young_holds() {
        checks::fenchel_young(&Quadratic, ZS, YS);
    }

    #[test]
    fn table1_constants() {
        assert_eq!(Quadratic.self_concordance_m(), 0.0);
        assert_eq!(Quadratic.smoothness(), 2.0);
    }

    #[test]
    fn sdca_delta_is_stationary_point() {
        // g(Δ) = (α+Δ)y − (α+Δ)²/4 − Δz − qΔ²/2 must have g'(Δ*) = 0.
        let (y, z, alpha, q) = (1.0, 0.3, -0.2, 0.8);
        let d = Quadratic.sdca_delta(y, z, alpha, q);
        let gp = y - (alpha + d) / 2.0 - z - q * d;
        assert!(gp.abs() < 1e-12);
    }

    #[test]
    fn sdca_delta_increases_dual_objective() {
        let (y, z, alpha, q) = (-1.0, 0.9, 0.4, 1.3);
        let g = |dd: f64| -> f64 {
            let a = alpha + dd;
            -(Quadratic.conjugate(-a, y)) - dd * z - q * dd * dd / 2.0
        };
        let d = Quadratic.sdca_delta(y, z, alpha, q);
        assert!(g(d) >= g(0.0));
        assert!(g(d) >= g(d + 0.1) - 1e-12);
        assert!(g(d) >= g(d - 0.1) - 1e-12);
    }
}
