//! The full regularized ERM objective (paper Eq. (P)) over a data matrix:
//!
//! ```text
//! f(w) = (1/n) Σ_i φ(wᵀx_i; y_i) + (λ/2)‖w‖²
//! ∇f(w) = (1/n) X g + λw,          g_i = φ'(wᵀx_i; y_i)
//! f''(w)u = (1/n) X diag(s) Xᵀu + λu,  s_i = φ''(wᵀx_i; y_i)
//! ```
//!
//! This is the single-machine ("oracle") view used by tests, reference
//! solvers, and as the per-shard local objective inside the distributed
//! algorithms (where `X` is a shard and the 1/n is the *global* n).

use crate::linalg::{ops, DataMatrix, HvpKernel};
use crate::loss::Loss;

pub struct Objective<'a> {
    pub x: &'a DataMatrix,
    pub y: &'a [f64],
    pub loss: &'a dyn Loss,
    pub lambda: f64,
    /// Divisor for the data-fitting term; equals the **global** sample
    /// count even when `x` is a shard.
    pub n_global: usize,
}

impl<'a> Objective<'a> {
    pub fn new(x: &'a DataMatrix, y: &'a [f64], loss: &'a dyn Loss, lambda: f64) -> Self {
        assert_eq!(x.ncols(), y.len(), "labels/sample mismatch");
        Self {
            x,
            y,
            loss,
            lambda,
            n_global: x.ncols(),
        }
    }

    /// Shard view: data-fitting divided by the global n; the regularizer
    /// is NOT included (the caller adds λw once globally).
    pub fn shard(x: &'a DataMatrix, y: &'a [f64], loss: &'a dyn Loss, n_global: usize) -> Self {
        assert_eq!(x.ncols(), y.len());
        Self {
            x,
            y,
            loss,
            lambda: 0.0,
            n_global,
        }
    }

    pub fn dim(&self) -> usize {
        self.x.nrows()
    }

    pub fn nsamples(&self) -> usize {
        self.x.ncols()
    }

    /// Margins `z = Xᵀw`.
    pub fn margins(&self, w: &[f64]) -> Vec<f64> {
        self.x.at_mul(w)
    }

    /// f(w) (with this objective's λ; 0 for shards).
    pub fn value(&self, w: &[f64]) -> f64 {
        let z = self.margins(w);
        let data: f64 = z
            .iter()
            .zip(self.y.iter())
            .map(|(zi, yi)| self.loss.value(*zi, *yi))
            .sum();
        data / self.n_global as f64 + 0.5 * self.lambda * ops::norm2_sq(w)
    }

    /// ∇f(w) into `out`.
    pub fn grad_into(&self, w: &[f64], out: &mut [f64]) {
        let z = self.margins(w);
        let g: Vec<f64> = z
            .iter()
            .zip(self.y.iter())
            .map(|(zi, yi)| self.loss.deriv(*zi, *yi))
            .collect();
        self.x.a_mul_into(&g, out);
        let inv_n = 1.0 / self.n_global as f64;
        for (oi, wi) in out.iter_mut().zip(w.iter()) {
            *oi = *oi * inv_n + self.lambda * *wi;
        }
    }

    pub fn grad(&self, w: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.grad_into(w, &mut out);
        out
    }

    /// Per-sample Hessian scalings `s_i = φ''(z_i; y_i)` at `w`.
    pub fn hessian_scalings(&self, w: &[f64]) -> Vec<f64> {
        self.margins(w)
            .iter()
            .zip(self.y.iter())
            .map(|(zi, yi)| self.loss.second_deriv(*zi, *yi))
            .collect()
    }

    /// Unfused reference Hessian-vector product `f''(w)·u` given
    /// precomputed scalings: three separate passes (gather, elementwise
    /// scale, scatter, plus the epilogue sweep) over the CSC layout.
    ///
    /// The PCG hot path uses [`Objective::hvp_with_kernel_into`] instead;
    /// this variant is kept as the equivalence oracle for tests and the
    /// honest A/B baseline in `bench_hotpaths`.
    pub fn hvp_with_scalings_into(
        &self,
        s: &[f64],
        u: &[f64],
        scratch_n: &mut [f64],
        out: &mut [f64],
    ) {
        assert_eq!(s.len(), self.nsamples());
        assert_eq!(scratch_n.len(), self.nsamples());
        self.x.at_mul_into(u, scratch_n); // t = Xᵀu
        for (ti, si) in scratch_n.iter_mut().zip(s.iter()) {
            *ti *= *si; // t ← s ⊙ t
        }
        self.x.a_mul_into(scratch_n, out); // out = X t
        let inv_n = 1.0 / self.n_global as f64;
        for (oi, ui) in out.iter_mut().zip(u.iter()) {
            *oi = *oi * inv_n + self.lambda * *ui;
        }
    }

    /// Build the fused hybrid HVP kernel for this objective's data matrix
    /// (CSR mirror per the layout heuristic). Build once per outer scope,
    /// then call [`Objective::hvp_with_kernel_into`] every PCG step.
    pub fn hvp_kernel(&self) -> HvpKernel {
        HvpKernel::new(self.x)
    }

    /// Fused HVP — the PCG hot path (Algorithm 2/3 step 4): two sweeps
    /// over the nonzeros, scalings and the `(1/n)·(…) + λu` epilogue
    /// folded in, zero allocation (`scratch_n`/`out` are caller-owned).
    pub fn hvp_with_kernel_into(
        &self,
        kernel: &HvpKernel,
        s: &[f64],
        u: &[f64],
        scratch_n: &mut [f64],
        out: &mut [f64],
    ) {
        assert_eq!(s.len(), self.nsamples());
        assert_eq!(scratch_n.len(), self.nsamples());
        let inv_n = 1.0 / self.n_global as f64;
        kernel.apply(self.x, s, u, inv_n, self.lambda, scratch_n, out);
    }

    /// Convenience allocating HVP at `w`.
    pub fn hvp(&self, w: &[f64], u: &[f64]) -> Vec<f64> {
        let s = self.hessian_scalings(w);
        let mut scratch = vec![0.0; self.nsamples()];
        let mut out = vec![0.0; self.dim()];
        self.hvp_with_scalings_into(&s, u, &mut scratch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CscMatrix;
    use crate::loss::{Logistic, Quadratic, SquaredHinge};
    use crate::util::prng::Xoshiro256pp;

    fn problem(seed: u64, d: usize, n: usize) -> (DataMatrix, Vec<f64>) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let x = DataMatrix::Sparse(CscMatrix::rand_sparse(d, n, 0.4, &mut rng));
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (x, y) = problem(1, 8, 12);
        for loss in [&Quadratic as &dyn crate::loss::Loss, &Logistic, &SquaredHinge] {
            let obj = Objective::new(&x, &y, loss, 0.1);
            let mut rng = Xoshiro256pp::seed_from_u64(2);
            let w: Vec<f64> = (0..8).map(|_| 0.3 * rng.normal()).collect();
            let g = obj.grad(&w);
            let h = 1e-6;
            for k in 0..8 {
                let mut wp = w.clone();
                let mut wm = w.clone();
                wp[k] += h;
                wm[k] -= h;
                let fd = (obj.value(&wp) - obj.value(&wm)) / (2.0 * h);
                assert!(
                    (fd - g[k]).abs() < 1e-4 * (1.0 + g[k].abs()),
                    "{}: coord {k}: {fd} vs {}",
                    loss.name(),
                    g[k]
                );
            }
        }
    }

    #[test]
    fn hvp_matches_grad_finite_differences() {
        let (x, y) = problem(3, 10, 15);
        let loss = Logistic;
        let obj = Objective::new(&x, &y, &loss, 0.05);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let w: Vec<f64> = (0..10).map(|_| 0.2 * rng.normal()).collect();
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let hv = obj.hvp(&w, &u);
        let h = 1e-6;
        let mut wp = w.clone();
        let mut wm = w.clone();
        for k in 0..10 {
            wp[k] = w[k] + h * u[k];
            wm[k] = w[k] - h * u[k];
        }
        let gp = obj.grad(&wp);
        let gm = obj.grad(&wm);
        for k in 0..10 {
            let fd = (gp[k] - gm[k]) / (2.0 * h);
            assert!((fd - hv[k]).abs() < 1e-5 * (1.0 + hv[k].abs()), "coord {k}");
        }
    }

    #[test]
    fn fused_kernel_hvp_matches_unfused() {
        let (x, y) = problem(11, 12, 18);
        let loss = Logistic;
        let obj = Objective::new(&x, &y, &loss, 0.07);
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let w: Vec<f64> = (0..12).map(|_| 0.3 * rng.normal()).collect();
        let u: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let s = obj.hessian_scalings(&w);
        let mut scratch = vec![0.0; 18];
        let mut unfused = vec![0.0; 12];
        obj.hvp_with_scalings_into(&s, &u, &mut scratch, &mut unfused);
        for use_csr in [false, true] {
            let kernel = crate::linalg::HvpKernel::with_layout(&x, use_csr);
            let mut fused = vec![0.0; 12];
            obj.hvp_with_kernel_into(&kernel, &s, &u, &mut scratch, &mut fused);
            for (a, b) in fused.iter().zip(unfused.iter()) {
                assert!(
                    (a - b).abs() < 1e-12 * (1.0 + b.abs()),
                    "csr={use_csr}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn hvp_is_linear_and_symmetric() {
        let (x, y) = problem(5, 9, 14);
        let loss = Logistic;
        let obj = Objective::new(&x, &y, &loss, 0.2);
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let w: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        // Linearity: H(u+2v) = Hu + 2Hv
        let mut upv = vec![0.0; 9];
        for k in 0..9 {
            upv[k] = u[k] + 2.0 * v[k];
        }
        let h_upv = obj.hvp(&w, &upv);
        let hu = obj.hvp(&w, &u);
        let hv = obj.hvp(&w, &v);
        for k in 0..9 {
            assert!((h_upv[k] - (hu[k] + 2.0 * hv[k])).abs() < 1e-10);
        }
        // Symmetry: vᵀHu = uᵀHv
        let a = ops::dot(&v, &hu);
        let b = ops::dot(&u, &hv);
        assert!((a - b).abs() < 1e-10 * (1.0 + a.abs()));
    }

    #[test]
    fn hvp_positive_definite_with_regularizer() {
        let (x, y) = problem(7, 6, 10);
        let loss = Quadratic;
        let obj = Objective::new(&x, &y, &loss, 0.3);
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let w: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        for _ in 0..10 {
            let u: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
            let hu = obj.hvp(&w, &u);
            let quad = ops::dot(&u, &hu);
            assert!(quad >= 0.3 * ops::norm2_sq(&u) - 1e-10);
        }
    }

    #[test]
    fn shard_objectives_sum_to_global() {
        // Gradient decomposition: Σ_shards ∇f_shard + λw = ∇f_global.
        let (x, y) = problem(9, 7, 20);
        let loss = Logistic;
        let lambda = 0.1;
        let obj = Objective::new(&x, &y, &loss, lambda);
        let w: Vec<f64> = (0..7).map(|i| 0.1 * i as f64).collect();
        let g_full = obj.grad(&w);

        let x1 = x.col_block(0, 12);
        let x2 = x.col_block(12, 20);
        let s1 = Objective::shard(&x1, &y[0..12], &loss, 20);
        let s2 = Objective::shard(&x2, &y[12..20], &loss, 20);
        let mut g = s1.grad(&w);
        let g2 = s2.grad(&w);
        for k in 0..7 {
            g[k] += g2[k] + lambda * w[k];
        }
        for k in 0..7 {
            assert!((g[k] - g_full[k]).abs() < 1e-12, "coord {k}");
        }
    }
}
