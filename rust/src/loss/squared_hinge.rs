//! Squared hinge loss `φ(z; y) = max(0, 1 − y·z)²` for labels `y ∈ {−1,+1}`
//! (L2-SVM). The paper's Table 1 writes `max(0, y − wᵀx)²`; for ±1 labels
//! the conventional margin form used here has the same smoothness (L = 2)
//! and self-concordance (M = 0) constants and is what the cited SDCA/CoCoA+
//! baselines implement.

use super::Loss;

#[derive(Clone, Copy, Debug, Default)]
pub struct SquaredHinge;

impl Loss for SquaredHinge {
    fn name(&self) -> &'static str {
        "squared_hinge"
    }

    #[inline]
    fn value(&self, z: f64, y: f64) -> f64 {
        let m = 1.0 - y * z;
        if m > 0.0 {
            m * m
        } else {
            0.0
        }
    }

    #[inline]
    fn deriv(&self, z: f64, y: f64) -> f64 {
        let m = 1.0 - y * z;
        if m > 0.0 {
            -2.0 * y * m
        } else {
            0.0
        }
    }

    #[inline]
    fn second_deriv(&self, z: f64, y: f64) -> f64 {
        if 1.0 - y * z > 0.0 {
            2.0
        } else {
            0.0
        }
    }

    fn smoothness(&self) -> f64 {
        2.0
    }

    fn self_concordance_m(&self) -> f64 {
        0.0
    }

    /// `φ*(u; y) = u·y + u²/4` on the half-line `u·y ≤ 0`, +∞ otherwise.
    fn conjugate(&self, u: f64, y: f64) -> f64 {
        if u * y > 1e-15 {
            return f64::INFINITY;
        }
        u * y + u * u / 4.0
    }

    /// Quadratic-loss step projected onto the dual-feasible half-line
    /// `(α+Δ)·y ≥ 0` (margin form: feasible dual is `α·y ∈ [0, ∞)`).
    #[inline]
    fn sdca_delta(&self, y: f64, z: f64, alpha: f64, q: f64) -> f64 {
        // Unconstrained maximizer of (α+Δ)y − (α+Δ)²/4 − Δz − qΔ²/2.
        let d = (y - z - alpha / 2.0) / (0.5 + q);
        if (alpha + d) * y >= 0.0 {
            d
        } else {
            -alpha // project to α_new = 0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::checks;

    // Stay away from the kink at y·z = 1 for FD checks.
    const ZS: &[f64] = &[-3.0, -0.8, 0.0, 0.5, 2.5];
    const YS: &[f64] = &[-1.0, 1.0];

    #[test]
    fn derivatives_match_finite_differences() {
        checks::grad_matches_fd(&SquaredHinge, ZS, YS);
        // second_deriv is discontinuous at the kink; check smooth regions.
        checks::hess_matches_fd(&SquaredHinge, &[-3.0, -0.8, 0.0, 0.5], &[1.0]);
    }

    #[test]
    fn zero_beyond_margin() {
        assert_eq!(SquaredHinge.value(2.0, 1.0), 0.0);
        assert_eq!(SquaredHinge.deriv(2.0, 1.0), 0.0);
        assert_eq!(SquaredHinge.second_deriv(2.0, 1.0), 0.0);
        assert!(SquaredHinge.value(-2.0, 1.0) > 0.0);
    }

    #[test]
    fn table1_constants() {
        assert_eq!(SquaredHinge.self_concordance_m(), 0.0);
        assert_eq!(SquaredHinge.smoothness(), 2.0);
    }

    #[test]
    fn fenchel_young_at_active_points() {
        // Equality u = φ'(z) only valid where conjugate finite; active side.
        for &z in &[-2.0, -0.5, 0.3] {
            let y = 1.0;
            let u = SquaredHinge.deriv(z, y);
            let lhs = SquaredHinge.value(z, y) + SquaredHinge.conjugate(u, y);
            assert!((lhs - u * z).abs() < 1e-9, "z={z}");
        }
    }

    #[test]
    fn sdca_delta_feasible_and_ascending() {
        for &(y, z, alpha, q) in &[
            (1.0, -0.5, 0.2, 0.8),
            (1.0, 3.0, 0.1, 0.5),  // step wants α negative ⇒ projected
            (-1.0, 0.7, -0.4, 2.0),
        ] {
            let g = |dd: f64| -> f64 {
                let c = SquaredHinge.conjugate(-(alpha + dd), y);
                if !c.is_finite() {
                    return f64::NEG_INFINITY;
                }
                -c - dd * z - q * dd * dd / 2.0
            };
            let d = SquaredHinge.sdca_delta(y, z, alpha, q);
            assert!((alpha + d) * y >= -1e-12, "dual infeasible");
            assert!(g(d) >= g(0.0) - 1e-12, "no ascent: {} vs {}", g(d), g(0.0));
        }
    }
}
