//! Logistic loss `φ(z; y) = log(1 + exp(−y·z))` for labels `y ∈ {−1, +1}`
//! (paper Table 1, M = 1).

use super::Loss;

#[derive(Clone, Copy, Debug, Default)]
pub struct Logistic;

/// Numerically-stable `log(1 + exp(x))`.
#[inline]
pub fn log1p_exp(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Stable sigmoid `1/(1+exp(−x))`.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

impl Loss for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    #[inline]
    fn value(&self, z: f64, y: f64) -> f64 {
        log1p_exp(-y * z)
    }

    /// `φ' = −y·σ(−y·z)`.
    #[inline]
    fn deriv(&self, z: f64, y: f64) -> f64 {
        -y * sigmoid(-y * z)
    }

    /// `φ'' = σ(y·z)·σ(−y·z)` — this is Eq. (9)'s scaling
    /// `exp(−wᵀx)/(1+exp(−wᵀx))²` generalized to ±1 labels.
    #[inline]
    fn second_deriv(&self, z: f64, y: f64) -> f64 {
        let s = sigmoid(y * z);
        s * (1.0 - s)
    }

    fn smoothness(&self) -> f64 {
        0.25
    }

    fn self_concordance_m(&self) -> f64 {
        1.0
    }

    /// `φ*(u; y)`: with `p = −u·y` (so `p ∈ [0,1]` on the domain),
    /// `φ* = p·log p + (1−p)·log(1−p)`; +∞ outside.
    fn conjugate(&self, u: f64, y: f64) -> f64 {
        let p = -u * y;
        if !(0.0..=1.0).contains(&p) {
            return f64::INFINITY;
        }
        let ent = |t: f64| if t <= 0.0 { 0.0 } else { t * t.ln() };
        ent(p) + ent(1.0 - p)
    }

    /// No closed form — the scalar concave maximization
    /// `g(Δ) = −φ*(−(α+Δ)) − Δz − qΔ²/2` is solved with safeguarded
    /// bisection on `g'` over the domain `(α+Δ)·y ∈ (0, 1)`.
    fn sdca_delta(&self, y: f64, z: f64, alpha: f64, q: f64) -> f64 {
        // Parametrize by s = (α+Δ)·y ∈ (0,1). Then
        //   −φ*(−(α+Δ)) = −[s ln s + (1−s) ln(1−s)]
        //   g(s) = entropy(s) − (s·y⁻¹?…)
        // Work directly in Δ. g'(Δ) = −y·ln(s/(1−s)) − z − qΔ where
        // s = (α+Δ)y; note dφ*(−a)/da = y·ln(s/(1−s)) with s = a·y.
        let s_of = |delta: f64| (alpha + delta) * y;
        let gprime = |delta: f64| -> f64 {
            let s = s_of(delta);
            -y * (s / (1.0 - s)).ln() - z - q * delta
        };
        // Domain of Δ: s ∈ (0,1) ⇒ Δ ∈ (lo, hi).
        let (lo, hi) = if y > 0.0 {
            (-alpha, 1.0 / y - alpha)
        } else {
            (1.0 / y - alpha, -alpha)
        };
        let eps = 1e-12 * (1.0 + hi - lo);
        let (mut a, mut b) = (lo + eps, hi - eps);
        // g is strictly concave; g' decreasing. If g' keeps one sign on the
        // whole open interval, optimum sits at that end.
        if gprime(a) <= 0.0 {
            return a;
        }
        if gprime(b) >= 0.0 {
            return b;
        }
        for _ in 0..60 {
            let m = 0.5 * (a + b);
            if gprime(m) > 0.0 {
                a = m;
            } else {
                b = m;
            }
        }
        0.5 * (a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::checks;

    const ZS: &[f64] = &[-4.0, -1.0, 0.0, 0.6, 3.0];
    const YS: &[f64] = &[-1.0, 1.0];

    #[test]
    fn derivatives_match_finite_differences() {
        checks::grad_matches_fd(&Logistic, ZS, YS);
        checks::hess_matches_fd(&Logistic, ZS, YS);
    }

    #[test]
    fn fenchel_young_holds() {
        checks::fenchel_young(&Logistic, ZS, YS);
    }

    #[test]
    fn table1_constants() {
        assert_eq!(Logistic.self_concordance_m(), 1.0);
        assert_eq!(Logistic.smoothness(), 0.25);
    }

    #[test]
    fn stable_at_extreme_margins() {
        assert!(Logistic.value(1e4, 1.0) >= 0.0);
        assert!(Logistic.value(-1e4, 1.0).is_finite());
        assert!(Logistic.second_deriv(1e4, 1.0) >= 0.0);
        assert!(Logistic.deriv(-1e4, 1.0).abs() <= 1.0);
    }

    #[test]
    fn second_deriv_bounded_by_quarter() {
        for z in [-5.0, -0.5, 0.0, 0.5, 5.0] {
            let s = Logistic.second_deriv(z, 1.0);
            assert!((0.0..=0.25 + 1e-15).contains(&s));
        }
        assert!((Logistic.second_deriv(0.0, 1.0) - 0.25).abs() < 1e-15);
    }

    #[test]
    fn sdca_delta_maximizes_dual_increment() {
        // Compare against a dense grid scan of the scalar objective.
        for &(y, z, alpha, q) in &[
            (1.0, 0.5, 0.5, 0.7),
            (-1.0, -0.2, -0.3, 1.5),
            (1.0, -2.0, 0.01, 0.2),
            (-1.0, 1.0, -0.9, 3.0),
        ] {
            let g = |dd: f64| -> f64 {
                let c = Logistic.conjugate(-(alpha + dd), y);
                if !c.is_finite() {
                    return f64::NEG_INFINITY;
                }
                -c - dd * z - q * dd * dd / 2.0
            };
            let d = Logistic.sdca_delta(y, z, alpha, q);
            let gd = g(d);
            assert!(gd.is_finite());
            // Grid scan over the feasible Δ interval.
            let (lo, hi) = if y > 0.0 {
                (-alpha, 1.0 / y - alpha)
            } else {
                (1.0 / y - alpha, -alpha)
            };
            let mut best = f64::NEG_INFINITY;
            for k in 1..400 {
                let dd = lo + (hi - lo) * k as f64 / 400.0;
                best = best.max(g(dd));
            }
            assert!(gd >= best - 1e-6, "y={y} z={z}: {gd} < grid {best}");
        }
    }

    #[test]
    fn sdca_keeps_dual_feasible() {
        let mut alpha = 0.5f64; // y=1 ⇒ feasible s=α·y ∈ (0,1)
        for step in 0..50 {
            let z = -0.8 + 0.03 * step as f64;
            let d = Logistic.sdca_delta(1.0, z, alpha, 0.9);
            alpha += d;
            assert!(alpha > 0.0 && alpha < 1.0, "infeasible α={alpha} at step {step}");
        }
    }
}
