//! Minimal read-only memory mapping, hand-rolled over the raw `mmap(2)`
//! syscall so the crate stays dependency-free.
//!
//! Only whole-file, `PROT_READ` + `MAP_PRIVATE` mappings are supported —
//! exactly what the shard reader needs. The mapping is immutable for its
//! lifetime, which is what lets [`crate::linalg::Buf`] hand out `&[T]`
//! views into it and mark them `Send + Sync`.
//!
//! # When mapping is disabled
//!
//! [`mmap_enabled`] gates the whole mapped path. It returns `false` under
//! Miri (no syscalls), on non-unix targets, on big-endian targets (the
//! shard format is little-endian on disk, so reinterpreting mapped bytes
//! would be wrong), and when `DISCO_NO_MMAP=1` is set (portability /
//! debugging escape hatch). When disabled, `ShardFile::open` falls back to
//! an explicit `read()` + `from_le_bytes` decode into heap buffers — same
//! values, same slices, just not zero-copy.

use std::fs::File;
use std::io;

/// Whether the zero-copy mapped path is available on this target/run.
pub fn mmap_enabled() -> bool {
    if cfg!(miri) || cfg!(not(unix)) || cfg!(target_endian = "big") {
        return false;
    }
    !matches!(std::env::var("DISCO_NO_MMAP"), Ok(v) if v == "1")
}

#[cfg(all(unix, not(miri)))]
mod sys {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    pub fn map(file: &File, len: usize) -> io::Result<*const u8> {
        if len == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "cannot map an empty file",
            ));
        }
        // MAP_FAILED is (void*)-1, not null.
        let failed = usize::MAX as *mut u8;
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == failed || ptr.is_null() {
            return Err(io::Error::last_os_error());
        }
        Ok(ptr as *const u8)
    }

    pub fn unmap(ptr: *const u8, len: usize) {
        unsafe {
            munmap(ptr as *mut u8, len);
        }
    }
}

/// A whole-file, read-only memory mapping.
pub struct Mmap {
    ptr: *const u8,
    len: usize,
}

// Sound: the mapping is PROT_READ/MAP_PRIVATE and never mutated or
// remapped until Drop, so shared references to its bytes are safe to send
// and share across threads.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `file` in its entirety. Fails when [`mmap_enabled`] is false —
    /// callers must check the policy first and take the decode fallback.
    pub fn map(file: &File) -> io::Result<Mmap> {
        if !mmap_enabled() {
            return Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "mmap disabled on this target/run (DISCO_NO_MMAP, miri, or non-unix)",
            ));
        }
        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "file too large to map"))?;
        #[cfg(all(unix, not(miri)))]
        {
            let ptr = sys::map(file, len)?;
            Ok(Mmap { ptr, len })
        }
        #[cfg(not(all(unix, not(miri))))]
        {
            unreachable!("mmap_enabled() is false on this target")
        }
    }

    #[inline]
    pub fn bytes(&self) -> &[u8] {
        // Sound: ptr is a live PROT_READ mapping of exactly `len` bytes.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, not(miri)))]
        sys::unmap(self.ptr, self.len);
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mmap[{} bytes]", self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_whole_file_or_reports_disabled() {
        let dir = std::env::temp_dir().join(format!("disco-mmap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("probe.bin");
        {
            let mut f = std::fs::File::create(&path).unwrap();
            f.write_all(&[1u8, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        }
        let f = std::fs::File::open(&path).unwrap();
        match Mmap::map(&f) {
            Ok(m) => {
                assert_eq!(m.len(), 8);
                assert_eq!(m.bytes(), &[1, 2, 3, 4, 5, 6, 7, 8]);
            }
            Err(e) => {
                assert!(!mmap_enabled(), "map failed while enabled: {e}");
            }
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
