//! Out-of-core shard store — the file-based data path (DESIGN.md §5).
//!
//! The paper's headline run minimizes over a 273 GB splice-site dataset;
//! no single node holds that in RAM. This module unbinds dataset size from
//! memory by replacing "materialize `X`, then slice" with "decide cuts
//! from metadata, then each rank opens *its own* shard file":
//!
//! * [`ingest`] streams a libsvm file (or a registry dataset) into a store
//!   directory: `store.json` (manifest), `labels.bin` (f64 labels),
//!   `rownnz.bin` (per-feature nnz histogram — partition-policy food), and
//!   one `shard-NNNN.dsh` column shard per rank. The streaming path's
//!   first pass gathers only `(n, d, row_nnz)`; the second writes one
//!   shard at a time. The global matrix is never resident.
//! * [`shard`] defines the `DSH1` shard container: versioned, checksummed
//!   (FNV-1a 64), little-endian, 8-aligned CSC sections plus an optional
//!   CSR mirror. Opened shards hand out [`CscMatrix`] views over the
//!   mapping (zero-copy) or decoded heap buffers when mapping is off.
//! * [`mmap`] is the dependency-free `mmap(2)` wrapper and its enable
//!   policy.
//! * [`StoreMatrix`] (this file) is the lazy, shard-granular
//!   `DataMatrix::Stored` backend: per-column ops and full products
//!   delegate to the owning shard **in global column order**, so every
//!   float op lands in the same sequence as the heap path — store-backed
//!   runs are bit-identical to heap-backed ones.

pub mod ingest;
pub mod mmap;
pub mod shard;

pub use mmap::{mmap_enabled, Mmap};
pub use shard::{write_shard, ShardFile, ShardWriteInfo};

use std::io::{self, Read};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::data::dataset::Dataset;
use crate::linalg::{Backing, CscMatrix, DataMatrix};
use crate::util::json::{self, Json};

pub const STORE_VERSION: u32 = 1;
pub const MANIFEST: &str = "store.json";
pub const LABELS: &str = "labels.bin";
pub const ROWNNZ: &str = "rownnz.bin";

/// FNV-1a 64-bit — the store's checksum. Hand-rolled (no deps), stable
/// across platforms, cheap enough to verify a whole shard at open.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// One shard's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardEntry {
    pub file: String,
    pub nnz: u64,
    pub checksum: u64,
}

/// Parsed `store.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreMeta {
    pub name: String,
    /// Samples (global columns).
    pub n: usize,
    /// Features (rows).
    pub d: usize,
    pub nnz: u64,
    /// Sample-axis cut table: shard `i` holds global columns
    /// `cuts[i].0 .. cuts[i].1`. Contiguous and covering `0..n`.
    pub cuts: Vec<(usize, usize)>,
    pub shards: Vec<ShardEntry>,
}

impl StoreMeta {
    pub fn m(&self) -> usize {
        self.cuts.len()
    }

    pub fn to_json(&self) -> Json {
        assert!(self.nnz < (1u64 << 53), "nnz exceeds JSON-safe integer range");
        json::obj(vec![
            ("version", json::num(STORE_VERSION as f64)),
            ("name", json::s(&self.name)),
            ("n", json::num(self.n as f64)),
            ("d", json::num(self.d as f64)),
            ("nnz", json::num(self.nnz as f64)),
            (
                "cuts",
                json::arr(
                    self.cuts
                        .iter()
                        .map(|&(s, e)| json::arr(vec![json::num(s as f64), json::num(e as f64)]))
                        .collect(),
                ),
            ),
            (
                "shards",
                json::arr(
                    self.shards
                        .iter()
                        .map(|sh| {
                            json::obj(vec![
                                ("file", json::s(&sh.file)),
                                ("nnz", json::num(sh.nnz as f64)),
                                ("checksum", json::s(&format!("{:#018x}", sh.checksum))),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<StoreMeta, String> {
        let version = j
            .get("version")
            .as_usize()
            .ok_or("manifest missing 'version'")?;
        if version != STORE_VERSION as usize {
            return Err(format!(
                "unsupported store version {version} (expected {STORE_VERSION})"
            ));
        }
        let name = j
            .get("name")
            .as_str()
            .ok_or("manifest missing 'name'")?
            .to_string();
        let n = j.get("n").as_usize().ok_or("manifest missing 'n'")?;
        let d = j.get("d").as_usize().ok_or("manifest missing 'd'")?;
        let nnz = j.get("nnz").as_f64().ok_or("manifest missing 'nnz'")? as u64;
        let mut cuts = Vec::new();
        for (i, c) in j
            .get("cuts")
            .as_arr()
            .ok_or("manifest missing 'cuts'")?
            .iter()
            .enumerate()
        {
            let pair = c.as_arr().ok_or(format!("cuts[{i}] is not a pair"))?;
            if pair.len() != 2 {
                return Err(format!("cuts[{i}] is not a pair"));
            }
            let s = pair[0].as_usize().ok_or(format!("cuts[{i}].0 invalid"))?;
            let e = pair[1].as_usize().ok_or(format!("cuts[{i}].1 invalid"))?;
            cuts.push((s, e));
        }
        let mut shards = Vec::new();
        for (i, sh) in j
            .get("shards")
            .as_arr()
            .ok_or("manifest missing 'shards'")?
            .iter()
            .enumerate()
        {
            let file = sh
                .get("file")
                .as_str()
                .ok_or(format!("shards[{i}] missing 'file'"))?
                .to_string();
            let snnz = sh
                .get("nnz")
                .as_f64()
                .ok_or(format!("shards[{i}] missing 'nnz'"))? as u64;
            let hex = sh
                .get("checksum")
                .as_str()
                .ok_or(format!("shards[{i}] missing 'checksum'"))?;
            let checksum = u64::from_str_radix(hex.trim_start_matches("0x"), 16)
                .map_err(|_| format!("shards[{i}] bad checksum '{hex}'"))?;
            shards.push(ShardEntry {
                file,
                nnz: snnz,
                checksum,
            });
        }
        let meta = StoreMeta {
            name,
            n,
            d,
            nnz,
            cuts,
            shards,
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<(), String> {
        if self.cuts.is_empty() || self.cuts.len() != self.shards.len() {
            return Err(format!(
                "manifest has {} cuts but {} shards",
                self.cuts.len(),
                self.shards.len()
            ));
        }
        if self.cuts[0].0 != 0 || self.cuts.last().unwrap().1 != self.n {
            return Err(format!("cuts do not cover 0..{}: {:?}", self.n, self.cuts));
        }
        for w in self.cuts.windows(2) {
            if w[0].1 != w[1].0 {
                return Err(format!("cuts have a gap or overlap: {:?}", self.cuts));
            }
        }
        if self.cuts.iter().any(|&(s, e)| e <= s) {
            return Err(format!("empty shard range in cuts: {:?}", self.cuts));
        }
        let total: u64 = self.shards.iter().map(|s| s.nnz).sum();
        if total != self.nnz {
            return Err(format!(
                "shard nnz sum {total} disagrees with manifest nnz {}",
                self.nnz
            ));
        }
        Ok(())
    }

    pub fn save(&self, dir: &Path) -> io::Result<()> {
        std::fs::write(dir.join(MANIFEST), format!("{}\n", self.to_json()))
    }

    pub fn load(dir: &Path) -> io::Result<StoreMeta> {
        let path = dir.join(MANIFEST);
        // Bounded: the manifest is a few KB of metadata, never matrix bytes.
        let text = std::fs::read_to_string(&path) // lint: allow(unbounded-read)
            .map_err(|e| io::Error::new(e.kind(), format!("{}: {e}", path.display())))?;
        let j = Json::parse(&text)
            .map_err(|e| bad(format!("{}: bad manifest JSON: {e}", path.display())))?;
        StoreMeta::from_json(&j).map_err(|e| bad(format!("{}: {e}", path.display())))
    }
}

fn read_f64s_file(path: &Path, n: usize) -> io::Result<Vec<f64>> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| io::Error::new(e.kind(), format!("{}: {e}", path.display())))?;
    let mut buf = vec![0u8; n * 8];
    f.read_exact(&mut buf)
        .map_err(|e| io::Error::new(e.kind(), format!("{}: {e}", path.display())))?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_u64s_file(path: &Path, n: usize) -> io::Result<Vec<u64>> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| io::Error::new(e.kind(), format!("{}: {e}", path.display())))?;
    let mut buf = vec![0u8; n * 8];
    f.read_exact(&mut buf)
        .map_err(|e| io::Error::new(e.kind(), format!("{}: {e}", path.display())))?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

struct StoreInner {
    dir: PathBuf,
    meta: StoreMeta,
    /// Per-feature nnz histogram, loaded eagerly from `rownnz.bin` (d·8
    /// bytes of metadata). Feeds the cost-balanced partition policies
    /// without touching any matrix bytes.
    row_nnz: Vec<u64>,
    /// Lazily opened shards. A rank that only extracts its own column
    /// block maps exactly one entry; nothing else is ever read.
    shards: Mutex<Vec<Option<Arc<CscMatrix>>>>,
}

/// The `DataMatrix::Stored` backend: a `d×n` sparse matrix whose columns
/// live in per-rank shard files, opened on demand.
///
/// Every operation visits columns in **global column order**, delegating
/// to the owning shard's `CscMatrix` — the identical float-op sequence as
/// the heap-backed matrix, hence bit-identical results.
///
/// IO errors after open (a shard file deleted mid-run, a checksum
/// mismatch) panic: matrix ops have no error channel, and a store that
/// validated at open and then lost a shard is not something an iteration
/// can recover from.
#[derive(Clone)]
pub struct StoreMatrix {
    inner: Arc<StoreInner>,
}

impl std::fmt::Debug for StoreMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "StoreMatrix[{} @ {}: {}x{}, {} shards]",
            self.inner.meta.name,
            self.inner.dir.display(),
            self.inner.meta.d,
            self.inner.meta.n,
            self.inner.meta.m()
        )
    }
}

impl StoreMatrix {
    /// Open a store directory's matrix (manifest + row histogram only; no
    /// shard bytes are touched until a column is needed).
    pub fn open(dir: &Path) -> io::Result<StoreMatrix> {
        let meta = StoreMeta::load(dir)?;
        let row_nnz = read_u64s_file(&dir.join(ROWNNZ), meta.d)?;
        let hist_total: u64 = row_nnz.iter().sum();
        if hist_total != meta.nnz {
            return Err(bad(format!(
                "{}: rownnz.bin sums to {hist_total}, manifest says {}",
                dir.display(),
                meta.nnz
            )));
        }
        let m = meta.m();
        Ok(StoreMatrix {
            inner: Arc::new(StoreInner {
                dir: dir.to_path_buf(),
                meta,
                row_nnz,
                shards: Mutex::new(vec![None; m]),
            }),
        })
    }

    pub fn nrows(&self) -> usize {
        self.inner.meta.d
    }

    pub fn ncols(&self) -> usize {
        self.inner.meta.n
    }

    pub fn nnz(&self) -> usize {
        self.inner.meta.nnz as usize
    }

    pub fn name(&self) -> &str {
        &self.inner.meta.name
    }

    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// The ingest-time sample-axis cut table (shard column ranges).
    pub fn cuts(&self) -> &[(usize, usize)] {
        &self.inner.meta.cuts
    }

    /// Per-feature nnz histogram (exact integer counts, from metadata).
    pub fn row_nnz(&self) -> &[u64] {
        &self.inner.row_nnz
    }

    /// Shard `i`'s matrix, opening (and caching) its file on first touch.
    pub fn shard(&self, i: usize) -> Arc<CscMatrix> {
        let mut cache = self.inner.shards.lock().unwrap();
        if let Some(m) = &cache[i] {
            return Arc::clone(m);
        }
        let entry = &self.inner.meta.shards[i];
        let path = self.inner.dir.join(&entry.file);
        let sf = ShardFile::open(&path)
            .unwrap_or_else(|e| panic!("store shard {}: {e}", path.display()));
        let (cs, ce) = self.inner.meta.cuts[i];
        assert_eq!(
            sf.col_range(),
            (cs, ce),
            "shard {} column range disagrees with manifest",
            entry.file
        );
        assert_eq!(sf.nrows(), self.inner.meta.d, "shard {} nrows", entry.file);
        assert_eq!(sf.nnz() as u64, entry.nnz, "shard {} nnz", entry.file);
        assert_eq!(
            sf.checksum(),
            entry.checksum,
            "shard {} checksum disagrees with manifest",
            entry.file
        );
        let m = Arc::new(sf.matrix());
        cache[i] = Some(Arc::clone(&m));
        m
    }

    /// Index of the shard holding global column `j`, plus `j` local to it.
    fn locate(&self, j: usize) -> (usize, usize) {
        let cuts = &self.inner.meta.cuts;
        let i = cuts.partition_point(|&(_, e)| e <= j);
        assert!(i < cuts.len(), "column {j} out of range ({})", self.ncols());
        (i, j - cuts[i].0)
    }

    pub fn col_dense(&self, j: usize) -> Vec<f64> {
        let (i, lj) = self.locate(j);
        self.shard(i).col_dense(lj)
    }

    pub fn col_dot(&self, j: usize, w: &[f64]) -> f64 {
        let (i, lj) = self.locate(j);
        let shard = self.shard(i);
        let (rows, vals) = shard.col(lj);
        let mut acc = 0.0;
        for (r, v) in rows.iter().zip(vals.iter()) {
            acc += *v * w[*r as usize];
        }
        acc
    }

    pub fn col_axpy(&self, j: usize, a: f64, w: &mut [f64]) {
        let (i, lj) = self.locate(j);
        let shard = self.shard(i);
        let (rows, vals) = shard.col(lj);
        for (r, v) in rows.iter().zip(vals.iter()) {
            w[*r as usize] += a * *v;
        }
    }

    pub fn col_norm_sq(&self, j: usize) -> f64 {
        let (i, lj) = self.locate(j);
        self.shard(i).col_norm_sq(lj)
    }

    /// `t ← Xᵀ u`, shard by shard in global column order — each shard
    /// writes its own disjoint `t` slice, identically to the heap sweep.
    pub fn at_mul_into(&self, u: &[f64], t: &mut [f64]) {
        assert_eq!(u.len(), self.nrows());
        assert_eq!(t.len(), self.ncols());
        for (i, &(s, e)) in self.inner.meta.cuts.iter().enumerate() {
            self.shard(i).at_mul_into(u, &mut t[s..e]);
        }
    }

    /// `y ← X t`. Replicates the heap scatter exactly: zero once, then
    /// columns in global order with the same `t[j] == 0` skip.
    pub fn a_mul_into(&self, t: &[f64], y: &mut [f64]) {
        assert_eq!(t.len(), self.ncols());
        assert_eq!(y.len(), self.nrows());
        for v in y.iter_mut() {
            *v = 0.0;
        }
        for (i, &(s, e)) in self.inner.meta.cuts.iter().enumerate() {
            let shard = self.shard(i);
            for lj in 0..(e - s) {
                let tj = t[s + lj];
                if tj == 0.0 {
                    continue;
                }
                let (rows, vals) = shard.col(lj);
                for (r, v) in rows.iter().zip(vals.iter()) {
                    y[*r as usize] += *v * tj;
                }
            }
        }
    }

    /// Column block `[start, end)`. When the range lies inside one shard
    /// this is that shard's zero-copy `col_block` (the common case: a
    /// rank extracting its own cut range, which ingest aligned to the
    /// shard boundaries). A spanning range is assembled on the heap —
    /// bounded by the requested range, never the whole matrix.
    pub fn col_block(&self, start: usize, end: usize) -> CscMatrix {
        assert!(start <= end && end <= self.ncols());
        if start == end {
            return CscMatrix::from_columns(self.nrows(), &[]);
        }
        let (i, ls) = self.locate(start);
        let (_, ie) = self.inner.meta.cuts[i];
        if end <= ie {
            return self.shard(i).col_block(ls, ls + (end - start));
        }
        let mut cols: Vec<Vec<(u32, f64)>> = Vec::with_capacity(end - start);
        let cuts = &self.inner.meta.cuts;
        for (si, &(s, e)) in cuts.iter().enumerate() {
            if e <= start || s >= end {
                continue;
            }
            let shard = self.shard(si);
            let lo = start.max(s) - s;
            let hi = end.min(e) - s;
            for lj in lo..hi {
                let (rows, vals) = shard.col(lj);
                cols.push(rows.iter().copied().zip(vals.iter().copied()).collect());
            }
        }
        CscMatrix::from_columns(self.nrows(), &cols)
    }

    /// Row block `[start, end)` — the DiSCO-F feature shard. Streams every
    /// shard's columns in global order, filtering and re-basing rows: the
    /// identical push sequence as `CscMatrix::row_block` over the heap
    /// matrix, so the result is bit-identical. Output is bounded by the
    /// block's nnz; input shards are visited one at a time.
    pub fn row_block(&self, start: usize, end: usize) -> CscMatrix {
        assert!(start <= end && end <= self.nrows());
        let mut colptr = Vec::with_capacity(self.ncols() + 1);
        let mut rowidx: Vec<u32> = Vec::new();
        let mut values: Vec<f64> = Vec::new();
        colptr.push(0);
        for (i, &(s, e)) in self.inner.meta.cuts.iter().enumerate() {
            let shard = self.shard(i);
            for lj in 0..(e - s) {
                let (rows, vals) = shard.col(lj);
                for (r, v) in rows.iter().zip(vals.iter()) {
                    let ri = *r as usize;
                    if ri >= start && ri < end {
                        rowidx.push((ri - start) as u32);
                        values.push(*v);
                    }
                }
                colptr.push(rowidx.len());
            }
        }
        CscMatrix::from_store_parts(end - start, colptr, rowidx.into(), values.into())
    }

    /// Dense materialization (tests / small stores only).
    pub fn to_dense(&self) -> crate::linalg::DenseMatrix {
        let mut m = crate::linalg::DenseMatrix::zeros(self.nrows(), self.ncols());
        for (i, &(s, e)) in self.inner.meta.cuts.iter().enumerate() {
            let shard = self.shard(i);
            for lj in 0..(e - s) {
                let (rows, vals) = shard.col(lj);
                for (r, v) in rows.iter().zip(vals.iter()) {
                    m.set(*r as usize, s + lj, *v);
                }
            }
        }
        m
    }

    /// How many shards are currently open (test/diagnostic hook: a rank
    /// that extracted its own block should have touched exactly one).
    pub fn shards_open(&self) -> usize {
        self.inner
            .shards
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.is_some())
            .count()
    }

    /// Backing of the shards this matrix would open ([`Backing::Mapped`]
    /// when the mmap policy is on; decoded heap buffers otherwise).
    pub fn backing(&self) -> Backing {
        if mmap_enabled() {
            Backing::Mapped
        } else {
            Backing::Heap
        }
    }
}

/// Open a store directory as a [`Dataset`] (labels eager — n·8 bytes —
/// matrix lazy/shard-granular).
pub fn open_dataset(dir: &Path) -> io::Result<Dataset> {
    let matrix = StoreMatrix::open(dir)?;
    let y = read_f64s_file(&dir.join(LABELS), matrix.ncols())?;
    let name = matrix.name().to_string();
    Ok(Dataset::new(&name, DataMatrix::Stored(matrix), y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn meta_round_trips_through_json() {
        let meta = StoreMeta {
            name: "tiny".into(),
            n: 10,
            d: 7,
            nnz: 30,
            cuts: vec![(0, 5), (5, 10)],
            shards: vec![
                ShardEntry {
                    file: "shard-0000.dsh".into(),
                    nnz: 14,
                    checksum: 0xdeadbeefcafef00d,
                },
                ShardEntry {
                    file: "shard-0001.dsh".into(),
                    nnz: 16,
                    checksum: 1,
                },
            ],
        };
        let text = meta.to_json().to_string();
        let back = StoreMeta::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, meta);
    }

    #[test]
    fn meta_validation_rejects_bad_cuts() {
        let mut meta = StoreMeta {
            name: "x".into(),
            n: 10,
            d: 4,
            nnz: 5,
            cuts: vec![(0, 4), (5, 10)],
            shards: vec![
                ShardEntry {
                    file: "a".into(),
                    nnz: 2,
                    checksum: 0,
                },
                ShardEntry {
                    file: "b".into(),
                    nnz: 3,
                    checksum: 0,
                },
            ],
        };
        assert!(meta.validate().unwrap_err().contains("gap"));
        meta.cuts = vec![(0, 5), (5, 10)];
        assert!(meta.validate().is_ok());
        meta.shards[0].nnz = 99;
        assert!(meta.validate().unwrap_err().contains("disagrees"));
    }
}
