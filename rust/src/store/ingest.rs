//! Streaming ingest: libsvm text (or an in-RAM registry dataset) → a
//! store directory of per-rank shard files.
//!
//! The libsvm path makes two passes through one reused `read_line`
//! buffer (shared token parsing with
//! [`parse_line`](crate::data::libsvm::parse_line)):
//!
//! 1. **Metadata** — `n`, `d` (max feature index), the per-feature nnz
//!    histogram, total nnz. Only counters are held; no matrix bytes.
//! 2. **Shards** — the sample-axis cut table (decided from pass-1
//!    metadata, before any matrix bytes exist) drives a second sweep that
//!    buffers exactly one shard's columns at a time, writing each
//!    [`write_shard`] as its cut boundary passes. Peak memory is the
//!    largest single shard plus the `n·8`-byte label vector — never the
//!    global matrix.
//!
//! `export_libsvm` is the inverse (with an optional repeat factor), used
//! to fabricate large on-disk inputs for the CI MaxRSS gate without ever
//! materializing them in one address space.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::data::dataset::Dataset;
use crate::data::libsvm::{parse_line, LibsvmError};
use crate::data::partition::balanced_ranges;
use crate::linalg::{CscMatrix, DataMatrix};
use crate::store::{write_shard, ShardEntry, StoreMeta, LABELS, ROWNNZ};
use crate::util::bytes::{put_f64s, put_u64};

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn parse_err(e: LibsvmError) -> io::Error {
    match e {
        LibsvmError::Io(e) => e,
        parse => bad(parse.to_string()),
    }
}

fn shard_name(i: usize) -> String {
    format!("shard-{i:04}.dsh")
}

struct Pass1 {
    n: usize,
    d: usize,
    nnz: u64,
    row_nnz: Vec<u64>,
}

/// First (cheap) pass: sample count, dimension, per-feature histogram.
fn scan_metadata(src: &Path, min_dim: usize) -> io::Result<Pass1> {
    let mut r = BufReader::new(File::open(src)?);
    let mut buf = String::new();
    let mut lineno = 0usize;
    let mut n = 0usize;
    let mut max_idx = 0usize;
    let mut nnz = 0u64;
    let mut row_nnz: Vec<u64> = Vec::new();
    loop {
        buf.clear();
        if r.read_line(&mut buf)? == 0 {
            break;
        }
        lineno += 1;
        let Some(p) = parse_line(&buf, lineno).map_err(parse_err)? else {
            continue;
        };
        n += 1;
        for &(i, _) in &p.col {
            let i = i as usize;
            if i >= row_nnz.len() {
                row_nnz.resize(i + 1, 0);
            }
            row_nnz[i] += 1;
            nnz += 1;
        }
        max_idx = max_idx.max(p.max_idx);
    }
    if n == 0 {
        return Err(bad(format!("{}: empty libsvm file", src.display())));
    }
    let d = max_idx.max(min_dim);
    row_nnz.resize(d, 0);
    Ok(Pass1 { n, d, nnz, row_nnz })
}

fn write_labels(dir: &Path, labels: &[f64]) -> io::Result<()> {
    let mut b = Vec::with_capacity(labels.len() * 8);
    put_f64s(&mut b, labels);
    std::fs::write(dir.join(LABELS), b)
}

fn write_rownnz(dir: &Path, row_nnz: &[u64]) -> io::Result<()> {
    let mut b = Vec::with_capacity(row_nnz.len() * 8);
    for &v in row_nnz {
        put_u64(&mut b, v);
    }
    std::fs::write(dir.join(ROWNNZ), b)
}

/// Stream a libsvm file into a store of `shards` column shards under
/// `dir`. The global matrix is never resident: pass 1 holds counters,
/// pass 2 holds one shard's columns. Returns the written manifest.
pub fn ingest_libsvm(
    src: &Path,
    dir: &Path,
    shards: usize,
    csr_mirror: bool,
    min_dim: usize,
) -> io::Result<StoreMeta> {
    assert!(shards > 0, "need at least one shard");
    let p1 = scan_metadata(src, min_dim)?;
    if p1.n < shards {
        return Err(bad(format!(
            "{}: cannot split {} samples into {shards} shards",
            src.display(),
            p1.n
        )));
    }
    let cuts = balanced_ranges(p1.n, shards);
    std::fs::create_dir_all(dir)?;
    let name = src
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());

    let mut r = BufReader::new(File::open(src)?);
    let mut buf = String::new();
    let mut lineno = 0usize;
    let mut labels: Vec<f64> = Vec::with_capacity(p1.n);
    let mut cols: Vec<Vec<(u32, f64)>> = Vec::new();
    let mut entries: Vec<ShardEntry> = Vec::new();
    let mut shard_i = 0usize;
    loop {
        buf.clear();
        if r.read_line(&mut buf)? == 0 {
            break;
        }
        lineno += 1;
        let Some(p) = parse_line(&buf, lineno).map_err(parse_err)? else {
            continue;
        };
        if p.max_idx > p1.d || labels.len() >= p1.n {
            return Err(bad(format!(
                "{}: file changed between ingest passes",
                src.display()
            )));
        }
        labels.push(p.label);
        cols.push(p.col);
        if shard_i < cuts.len() && labels.len() == cuts[shard_i].1 {
            let m = CscMatrix::from_columns(p1.d, &cols);
            let info = write_shard(
                &dir.join(shard_name(shard_i)),
                &m,
                cuts[shard_i].0,
                csr_mirror,
            )?;
            entries.push(ShardEntry {
                file: shard_name(shard_i),
                nnz: info.nnz,
                checksum: info.checksum,
            });
            cols.clear();
            shard_i += 1;
        }
    }
    if labels.len() != p1.n || shard_i != cuts.len() {
        return Err(bad(format!(
            "{}: file changed between ingest passes ({} of {} samples seen)",
            src.display(),
            labels.len(),
            p1.n
        )));
    }
    write_labels(dir, &labels)?;
    write_rownnz(dir, &p1.row_nnz)?;
    let meta = StoreMeta {
        name,
        n: p1.n,
        d: p1.d,
        nnz: p1.nnz,
        cuts,
        shards: entries,
    };
    meta.save(dir)?;
    Ok(meta)
}

/// Write an in-RAM (sparse) dataset — e.g. a registry synthetic — into a
/// store of `shards` column shards. The generator already materialized
/// the matrix, so this path is about producing the on-disk layout, not
/// about memory; shards are zero-copy column views of the source.
pub fn ingest_dataset(
    ds: &Dataset,
    dir: &Path,
    shards: usize,
    csr_mirror: bool,
) -> io::Result<StoreMeta> {
    assert!(shards > 0, "need at least one shard");
    let sp = match &ds.x {
        DataMatrix::Sparse(m) => m,
        other => {
            return Err(io::Error::new(
                io::ErrorKind::Unsupported,
                format!(
                    "only sparse in-RAM datasets can be written to a store (got {})",
                    match other {
                        DataMatrix::Dense(_) => "dense",
                        _ => "store-backed",
                    }
                ),
            ))
        }
    };
    let n = ds.nsamples();
    if n < shards {
        return Err(bad(format!(
            "cannot split {n} samples into {shards} shards"
        )));
    }
    let cuts = balanced_ranges(n, shards);
    std::fs::create_dir_all(dir)?;
    let mut row_nnz = vec![0u64; ds.dim()];
    for j in 0..n {
        let (rows, _) = sp.col(j);
        for r in rows {
            row_nnz[*r as usize] += 1;
        }
    }
    let mut entries = Vec::with_capacity(shards);
    for (i, &(s, e)) in cuts.iter().enumerate() {
        let block = sp.col_block(s, e);
        let info = write_shard(&dir.join(shard_name(i)), &block, s, csr_mirror)?;
        entries.push(ShardEntry {
            file: shard_name(i),
            nnz: info.nnz,
            checksum: info.checksum,
        });
    }
    write_labels(dir, &ds.y)?;
    write_rownnz(dir, &row_nnz)?;
    let meta = StoreMeta {
        name: ds.name.clone(),
        n,
        d: ds.dim(),
        nnz: sp.nnz() as u64,
        cuts,
        shards: entries,
    };
    meta.save(dir)?;
    Ok(meta)
}

/// Stream a dataset out as libsvm text, `repeat` ≥ 1 concatenated copies.
/// Values print with Rust's shortest-round-trip `f64` formatting, so
/// re-ingesting reproduces them bit-exactly. Used to fabricate inputs
/// larger than any in-RAM dataset for the CI MaxRSS gate.
pub fn export_libsvm(ds: &Dataset, path: &Path, repeat: usize) -> io::Result<()> {
    let repeat = repeat.max(1);
    let mut f = BufWriter::new(File::create(path)?);
    for _ in 0..repeat {
        for j in 0..ds.nsamples() {
            write!(f, "{}", ds.y[j])?;
            match &ds.x {
                DataMatrix::Sparse(m) => {
                    let (rows, vals) = m.col(j);
                    for (r, v) in rows.iter().zip(vals.iter()) {
                        write!(f, " {}:{}", *r as usize + 1, v)?;
                    }
                }
                other => {
                    for (i, v) in other.col_dense(j).iter().enumerate() {
                        if *v != 0.0 {
                            write!(f, " {}:{}", i + 1, v)?;
                        }
                    }
                }
            }
            writeln!(f)?;
        }
    }
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm;
    use crate::store::open_dataset;
    use crate::util::prng::Xoshiro256pp;
    use std::io::Cursor;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "disco-ingest-test-{}-{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Random libsvm text with comments, blank lines, index gaps, ragged
    /// nnz per line.
    fn random_libsvm(rng: &mut Xoshiro256pp, n: usize, d: usize) -> String {
        let mut out = String::new();
        out.push_str("# header comment\n");
        for s in 0..n {
            if rng.next_f64() < 0.1 {
                out.push('\n'); // blank line
            }
            if rng.next_f64() < 0.1 {
                out.push_str("# interior comment\n");
            }
            let label = if rng.next_f64() < 0.5 { 1.0 } else { -1.0 };
            out.push_str(&format!("{label}"));
            let mut idx: Vec<usize> = (1..=d).filter(|_| rng.next_f64() < 0.3).collect();
            if idx.is_empty() && s == 0 {
                idx.push(d); // pin the dimension
            }
            // Scramble order: the parser must sort.
            if idx.len() > 1 && rng.next_f64() < 0.5 {
                idx.reverse();
            }
            for i in idx {
                out.push_str(&format!(" {}:{}", i, rng.normal()));
            }
            if rng.next_f64() < 0.2 {
                out.push_str(" # trailing comment");
            }
            out.push('\n');
        }
        out
    }

    #[test]
    fn streamed_ingest_matches_one_shot_parse_bit_for_bit() {
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        for (case, (n, d)) in [(0usize, (13usize, 9usize)), (1, (29, 17)), (2, (64, 5))]
            .into_iter()
        {
            let text = random_libsvm(&mut rng, n, d);
            let heap = libsvm::parse_reader(Cursor::new(&text), "case", 0).unwrap();
            let dir = tmp_dir(&format!("prop{case}"));
            let src = dir.join("case.libsvm");
            std::fs::write(&src, &text).unwrap();
            // Shard counts chosen to exercise ragged cut boundaries.
            for shards in [1usize, 2, 3, 5] {
                let sub = dir.join(format!("store{shards}"));
                let meta = ingest_libsvm(&src, &sub, shards, false, 0).unwrap();
                assert_eq!(meta.m(), shards);
                let stored = open_dataset(&sub).unwrap();
                assert_eq!(stored.nsamples(), heap.nsamples());
                assert_eq!(stored.dim(), heap.dim());
                assert_eq!(stored.nnz(), heap.nnz());
                // Labels and every column, bit-for-bit.
                for (a, b) in stored.y.iter().zip(heap.y.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                for j in 0..heap.nsamples() {
                    let (a, b) = (stored.x.col_dense(j), heap.x.col_dense(j));
                    for (x, y) in a.iter().zip(b.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "col {j}");
                    }
                }
            }
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    #[test]
    fn ingest_dataset_round_trips() {
        use crate::data::synthetic::SyntheticConfig;
        let ds = SyntheticConfig::new("rt", 40, 30).seed(5).generate();
        let dir = tmp_dir("dataset");
        let meta = ingest_dataset(&ds, &dir, 4, true).unwrap();
        assert_eq!(meta.n, 30);
        assert_eq!(meta.nnz as usize, ds.nnz());
        let back = open_dataset(&dir).unwrap();
        assert_eq!(back.name, "rt");
        for (a, b) in back.y.iter().zip(ds.y.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for j in 0..ds.nsamples() {
            let (a, b) = (back.x.col_dense(j), ds.x.col_dense(j));
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn export_then_ingest_preserves_values_bitwise() {
        use crate::data::synthetic::SyntheticConfig;
        let ds = SyntheticConfig::new("ex", 15, 12).seed(9).generate();
        let dir = tmp_dir("export");
        let path = dir.join("ex.libsvm");
        export_libsvm(&ds, &path, 2).unwrap();
        let back = libsvm::load(&path).unwrap();
        assert_eq!(back.nsamples(), 2 * ds.nsamples());
        for j in 0..ds.nsamples() {
            for rep in [j, j + ds.nsamples()] {
                assert_eq!(back.y[rep].to_bits(), ds.y[j].to_bits());
                let (a, b) = (back.x.col_dense(rep), ds.x.col_dense(j));
                for (x, y) in a.iter().zip(b.iter().take(a.len())) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ingest_refuses_oversharding_and_empty() {
        let dir = tmp_dir("refuse");
        let src = dir.join("two.libsvm");
        std::fs::write(&src, "1 1:1\n-1 2:1\n").unwrap();
        assert!(ingest_libsvm(&src, &dir.join("s"), 3, false, 0).is_err());
        let empty = dir.join("empty.libsvm");
        std::fs::write(&empty, "# only a comment\n").unwrap();
        let err = ingest_libsvm(&empty, &dir.join("e"), 1, false, 0).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rank_extraction_opens_one_shard() {
        use crate::data::synthetic::SyntheticConfig;
        let ds = SyntheticConfig::new("lazy", 30, 24).seed(6).generate();
        let dir = tmp_dir("lazy");
        let meta = ingest_dataset(&ds, &dir, 4, false).unwrap();
        let stored = open_dataset(&dir).unwrap();
        let sm = match &stored.x {
            DataMatrix::Stored(m) => m.clone(),
            _ => panic!("expected a store-backed matrix"),
        };
        assert_eq!(sm.shards_open(), 0, "open must not touch shard bytes");
        let (s, e) = meta.cuts[2];
        let _block = stored.x.col_block(s, e);
        assert_eq!(sm.shards_open(), 1, "one rank's extraction maps one shard");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
