//! `ShardFile` — the on-disk container for one rank's column shard.
//!
//! Layout (all integers little-endian, sections 8-byte aligned):
//!
//! | offset | bytes          | field                                      |
//! |-------:|----------------|--------------------------------------------|
//! | 0      | 4              | magic `DSH1`                               |
//! | 4      | 4              | format version (`1`)                       |
//! | 8      | 4              | flags (bit 0: CSR mirror present)          |
//! | 12     | 4              | reserved (zero)                            |
//! | 16     | 8              | `nrows` (features d)                       |
//! | 24     | 8              | `ncols` (samples in this shard)            |
//! | 32     | 8              | `col_start` (global column of local col 0) |
//! | 40     | 8              | `nnz`                                      |
//! | 48     | 8              | FNV-1a 64 checksum of all bytes after 64   |
//! | 56     | 8              | reserved (zero)                            |
//! | 64     | `(ncols+1)·8`  | `colptr: u64[]`, local (`colptr[0] = 0`)   |
//! |        | `nnz·4` (+pad) | `rowidx: u32[]`                            |
//! |        | `nnz·8`        | `values: f64[]`                            |
//!
//! With flag bit 0, a CSR mirror of the same nonzeros follows: `rowptr:
//! u64[nrows+1]`, `colidx: u32[nnz]` (+pad), `values: f64[nnz]` — written
//! by the same [`CsrMatrix::from_csc`] conversion the runtime kernel uses,
//! so the file mirror is bit-identical to what the kernel would build.
//!
//! Opening validates magic, version, exact file size, and the checksum,
//! then exposes the CSC arrays as [`Buf`] windows into the mapping
//! (zero-copy) — or, when [`mmap_enabled`](super::mmap::mmap_enabled) is
//! false, decodes them into heap buffers via `from_le_bytes`. Both paths
//! yield byte-identical slices on little-endian hosts, and the decode path
//! is also correct on big-endian ones.

use std::fs::File;
use std::io::{self, Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::linalg::{Backing, Buf, CscMatrix, CsrMatrix};
use crate::store::fnv1a64;
use crate::store::mmap::{mmap_enabled, Mmap};
use crate::util::bytes::{put_f64s, put_u32, put_u64};

pub const SHARD_MAGIC: [u8; 4] = *b"DSH1";
pub const SHARD_VERSION: u32 = 1;
const FLAG_CSR_MIRROR: u32 = 1;
const HEADER_LEN: usize = 64;

fn align8(x: usize) -> usize {
    (x + 7) & !7
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn pad8(buf: &mut Vec<u8>) {
    while buf.len() % 8 != 0 {
        buf.push(0);
    }
}

/// Summary of a written shard, recorded in `store.json`.
#[derive(Clone, Debug)]
pub struct ShardWriteInfo {
    pub nnz: u64,
    pub checksum: u64,
    pub bytes: u64,
}

/// Serialize `m` (one rank's column shard; `col_start` is the global
/// column index of its local column 0) to `path`. Returns nnz/checksum
/// for the store manifest.
pub fn write_shard(
    path: &Path,
    m: &CscMatrix,
    col_start: usize,
    with_mirror: bool,
) -> io::Result<ShardWriteInfo> {
    let nnz = m.nnz();
    let ncols = m.ncols();
    let mut body = Vec::with_capacity((ncols + 1) * 8 + align8(nnz * 4) + nnz * 8);
    let mut acc = 0u64;
    put_u64(&mut body, 0);
    for j in 0..ncols {
        acc += m.col(j).0.len() as u64;
        put_u64(&mut body, acc);
    }
    for j in 0..ncols {
        for &r in m.col(j).0 {
            put_u32(&mut body, r);
        }
    }
    pad8(&mut body);
    for j in 0..ncols {
        put_f64s(&mut body, m.col(j).1);
    }
    if with_mirror {
        let csr = CsrMatrix::from_csc(m);
        for &p in csr.rowptr() {
            put_u64(&mut body, p as u64);
        }
        for i in 0..csr.nrows() {
            for &c in csr.row(i).0 {
                put_u32(&mut body, c);
            }
        }
        pad8(&mut body);
        for i in 0..csr.nrows() {
            put_f64s(&mut body, csr.row(i).1);
        }
    }
    let checksum = fnv1a64(&body);

    let mut hdr = Vec::with_capacity(HEADER_LEN);
    hdr.extend_from_slice(&SHARD_MAGIC);
    put_u32(&mut hdr, SHARD_VERSION);
    put_u32(&mut hdr, if with_mirror { FLAG_CSR_MIRROR } else { 0 });
    put_u32(&mut hdr, 0);
    put_u64(&mut hdr, m.nrows() as u64);
    put_u64(&mut hdr, ncols as u64);
    put_u64(&mut hdr, col_start as u64);
    put_u64(&mut hdr, nnz as u64);
    put_u64(&mut hdr, checksum);
    put_u64(&mut hdr, 0);
    debug_assert_eq!(hdr.len(), HEADER_LEN);

    let mut f = File::create(path)?;
    f.write_all(&hdr)?;
    f.write_all(&body)?;
    f.sync_all()?;
    Ok(ShardWriteInfo {
        nnz: nnz as u64,
        checksum,
        bytes: (HEADER_LEN + body.len()) as u64,
    })
}

/// Byte offsets (absolute into the file) of the post-header sections.
struct Sections {
    colptr: usize,
    rowidx: usize,
    values: usize,
    mirror: Option<MirrorSections>,
    total: usize,
}

struct MirrorSections {
    rowptr: usize,
    colidx: usize,
    values: usize,
}

fn layout(nrows: usize, ncols: usize, nnz: usize, with_mirror: bool) -> Option<Sections> {
    let colptr = HEADER_LEN;
    let rowidx = colptr.checked_add(ncols.checked_add(1)?.checked_mul(8)?)?;
    let values = align8(rowidx.checked_add(nnz.checked_mul(4)?)?);
    let mut total = values.checked_add(nnz.checked_mul(8)?)?;
    let mirror = if with_mirror {
        let rowptr = total;
        let colidx = rowptr.checked_add(nrows.checked_add(1)?.checked_mul(8)?)?;
        let mvalues = align8(colidx.checked_add(nnz.checked_mul(4)?)?);
        total = mvalues.checked_add(nnz.checked_mul(8)?)?;
        Some(MirrorSections {
            rowptr,
            colidx,
            values: mvalues,
        })
    } else {
        None
    };
    Some(Sections {
        colptr,
        rowidx,
        values,
        mirror,
        total,
    })
}

fn u64s_at(bytes: &[u8], off: usize, n: usize) -> Vec<u64> {
    bytes[off..off + n * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn u32s_at(bytes: &[u8], off: usize, n: usize) -> Vec<u32> {
    bytes[off..off + n * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn f64s_at(bytes: &[u8], off: usize, n: usize) -> Vec<f64> {
    bytes[off..off + n * 8]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// An opened, verified shard. The CSC arrays stay backed by the mapping
/// (or by decoded heap buffers when mapping is disabled) for the life of
/// any [`CscMatrix`] handed out by [`ShardFile::matrix`].
pub struct ShardFile {
    nrows: usize,
    ncols: usize,
    col_start: usize,
    nnz: usize,
    checksum: u64,
    matrix: CscMatrix,
    mirror: Option<CsrMatrix>,
}

impl ShardFile {
    pub fn open(path: &Path) -> io::Result<ShardFile> {
        let mut file = File::open(path)?;
        let file_len = usize::try_from(file.metadata()?.len())
            .map_err(|_| bad(format!("{}: shard file too large for this host", path.display())))?;
        if file_len < HEADER_LEN {
            return Err(bad(format!(
                "{}: truncated shard file ({file_len} bytes, header needs {HEADER_LEN})",
                path.display()
            )));
        }
        let mut hdr = [0u8; HEADER_LEN];
        file.read_exact(&mut hdr)?;
        if hdr[0..4] != SHARD_MAGIC {
            return Err(bad(format!(
                "{}: bad magic {:02x?} (not a DSH1 shard file)",
                path.display(),
                &hdr[0..4]
            )));
        }
        let mut r = crate::util::bytes::ByteReader::new(&hdr[4..]);
        let parse = |e: String| bad(format!("{}: bad shard header: {e}", path.display()));
        let version = r.u32().map_err(parse)?;
        if version != SHARD_VERSION {
            return Err(bad(format!(
                "{}: unsupported shard version {version} (expected {SHARD_VERSION})",
                path.display()
            )));
        }
        let parse = |e: String| bad(format!("{}: bad shard header: {e}", path.display()));
        let flags = r.u32().map_err(parse)?;
        let parse = |e: String| bad(format!("{}: bad shard header: {e}", path.display()));
        let _reserved = r.u32().map_err(parse)?;
        let mut usize_field = |name: &str| -> io::Result<usize> {
            let v = r
                .u64()
                .map_err(|e| bad(format!("{}: bad shard header: {e}", path.display())))?;
            usize::try_from(v)
                .map_err(|_| bad(format!("{}: {name} {v} overflows usize", path.display())))
        };
        let nrows = usize_field("nrows")?;
        let ncols = usize_field("ncols")?;
        let col_start = usize_field("col_start")?;
        let nnz = usize_field("nnz")?;
        let checksum = r
            .u64()
            .map_err(|e| bad(format!("{}: bad shard header: {e}", path.display())))?;
        let with_mirror = flags & FLAG_CSR_MIRROR != 0;
        let sec = layout(nrows, ncols, nnz, with_mirror)
            .ok_or_else(|| bad(format!("{}: shard dimensions overflow", path.display())))?;
        if file_len != sec.total {
            return Err(bad(format!(
                "{}: truncated or oversized shard file: expected {} bytes, found {file_len}",
                path.display(),
                sec.total
            )));
        }

        // From here the two backings diverge only in where the bytes live.
        let bytes_holder: ShardBytes;
        let rowidx: Buf<u32>;
        let values: Buf<f64>;
        if mmap_enabled() {
            let map = Arc::new(Mmap::map(&file)?);
            let got = fnv1a64(&map.bytes()[HEADER_LEN..]);
            if got != checksum {
                return Err(bad(format!(
                    "{}: checksum mismatch: header {checksum:#018x}, computed {got:#018x}",
                    path.display()
                )));
            }
            rowidx = Buf::mapped(Arc::clone(&map), sec.rowidx, nnz);
            values = Buf::mapped(Arc::clone(&map), sec.values, nnz);
            bytes_holder = ShardBytes::Mapped(map);
        } else {
            let mut rest = Vec::with_capacity(file_len - HEADER_LEN);
            // Bounded by construction: reads exactly one shard file whose
            // size was just validated against the header.
            file.read_to_end(&mut rest)?; // lint: allow(unbounded-read)
            if rest.len() != file_len - HEADER_LEN {
                return Err(bad(format!(
                    "{}: short read: got {} body bytes, expected {}",
                    path.display(),
                    rest.len(),
                    file_len - HEADER_LEN
                )));
            }
            let got = fnv1a64(&rest);
            if got != checksum {
                return Err(bad(format!(
                    "{}: checksum mismatch: header {checksum:#018x}, computed {got:#018x}",
                    path.display()
                )));
            }
            // Offsets in `sec` are absolute; the heap body starts at 64.
            rowidx = u32s_at(&rest, sec.rowidx - HEADER_LEN, nnz).into();
            values = f64s_at(&rest, sec.values - HEADER_LEN, nnz).into();
            bytes_holder = ShardBytes::Heap(rest);
        }

        let raw_colptr = match &bytes_holder {
            ShardBytes::Mapped(map) => u64s_at(map.bytes(), sec.colptr, ncols + 1),
            ShardBytes::Heap(body) => u64s_at(body, sec.colptr - HEADER_LEN, ncols + 1),
        };
        let mut colptr = Vec::with_capacity(ncols + 1);
        for (j, &p) in raw_colptr.iter().enumerate() {
            let p = usize::try_from(p)
                .map_err(|_| bad(format!("{}: colptr[{j}] overflows usize", path.display())))?;
            if p > nnz || colptr.last().is_some_and(|&l| p < l) {
                return Err(bad(format!(
                    "{}: corrupt colptr at column {j} (value {p}, nnz {nnz})",
                    path.display()
                )));
            }
            colptr.push(p);
        }
        if colptr[0] != 0 || colptr[ncols] != nnz {
            return Err(bad(format!(
                "{}: corrupt colptr endpoints (start {}, end {}, nnz {nnz})",
                path.display(),
                colptr[0],
                colptr[ncols]
            )));
        }

        let matrix = CscMatrix::from_store_parts(nrows, colptr, rowidx, values);

        let mirror = match (&sec.mirror, &bytes_holder) {
            (None, _) => None,
            (Some(ms), holder) => {
                let (bytes, base) = match holder {
                    ShardBytes::Mapped(map) => (map.bytes(), 0usize),
                    ShardBytes::Heap(body) => (body.as_slice(), HEADER_LEN),
                };
                let rowptr: Vec<usize> = u64s_at(bytes, ms.rowptr - base, nrows + 1)
                    .into_iter()
                    .map(|p| p as usize)
                    .collect();
                let colidx = u32s_at(bytes, ms.colidx - base, nnz);
                let mvals = f64s_at(bytes, ms.values - base, nnz);
                Some(CsrMatrix::from_parts(nrows, ncols, rowptr, colidx, mvals))
            }
        };

        Ok(ShardFile {
            nrows,
            ncols,
            col_start,
            nnz,
            checksum,
            matrix,
            mirror,
        })
    }

    /// The shard as a [`CscMatrix`] over the file's buffers (cheap clone:
    /// buffer handles + the small `colptr`).
    pub fn matrix(&self) -> CscMatrix {
        self.matrix.clone()
    }

    /// Decoded CSR mirror, when the shard was written with one. Heap
    /// buffers — the mirror is an opt-in extra, not part of the zero-copy
    /// path.
    pub fn csr_mirror(&self) -> Option<CsrMatrix> {
        self.mirror.clone()
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Global column range `[col_start, col_start + ncols)` this shard
    /// covers.
    pub fn col_range(&self) -> (usize, usize) {
        (self.col_start, self.col_start + self.ncols)
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn checksum(&self) -> u64 {
        self.checksum
    }

    pub fn backing(&self) -> Backing {
        self.matrix.backing()
    }
}

/// Keeps the shard's bytes alive alongside the decoded views. (In the
/// mapped case the `CscMatrix` buffers also hold the map; this exists so
/// the mirror decode can reach the raw bytes uniformly.)
enum ShardBytes {
    Mapped(Arc<Mmap>),
    Heap(Vec<u8>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256pp;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("disco-shard-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample(seed: u64) -> CscMatrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        CscMatrix::rand_sparse(23, 17, 0.2, &mut rng)
    }

    #[test]
    fn round_trips_matrix_and_mirror() {
        let m = sample(41);
        let path = tmp("roundtrip.dsh");
        let info = write_shard(&path, &m, 5, true).unwrap();
        assert_eq!(info.nnz as usize, m.nnz());
        let sf = ShardFile::open(&path).unwrap();
        assert_eq!(sf.col_range(), (5, 5 + 17));
        assert_eq!(sf.nnz(), m.nnz());
        assert_eq!(sf.checksum(), info.checksum);
        let got = sf.matrix();
        assert_eq!(got, m);
        // The file mirror is the same conversion the runtime kernel does.
        assert_eq!(sf.csr_mirror().unwrap(), CsrMatrix::from_csc(&m));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn no_mirror_when_not_requested() {
        let m = sample(42);
        let path = tmp("nomirror.dsh");
        write_shard(&path, &m, 0, false).unwrap();
        let sf = ShardFile::open(&path).unwrap();
        assert!(sf.csr_mirror().is_none());
        assert_eq!(sf.matrix(), m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupted_byte_fails_checksum() {
        let m = sample(43);
        let path = tmp("corrupt.dsh");
        write_shard(&path, &m, 0, false).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = ShardFile::open(&path).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_file_refused() {
        let m = sample(44);
        let path = tmp("truncated.dsh");
        write_shard(&path, &m, 0, false).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        let err = ShardFile::open(&path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // Header alone is also refused.
        std::fs::write(&path, &bytes[..40]).unwrap();
        let err = ShardFile::open(&path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bad_magic_and_version_refused() {
        let m = sample(45);
        let path = tmp("magic.dsh");
        write_shard(&path, &m, 0, false).unwrap();
        let good = std::fs::read(&path).unwrap();
        let mut bytes = good.clone();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardFile::open(&path).unwrap_err().to_string().contains("bad magic"));
        let mut bytes = good;
        bytes[4] = 9;
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardFile::open(&path)
            .unwrap_err()
            .to_string()
            .contains("unsupported shard version"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn decode_fallback_is_bit_identical_to_mapped() {
        let m = sample(46);
        let path = tmp("fallback.dsh");
        write_shard(&path, &m, 2, true).unwrap();
        let mapped = ShardFile::open(&path).unwrap();
        std::env::set_var("DISCO_NO_MMAP", "1");
        let decoded = ShardFile::open(&path);
        std::env::remove_var("DISCO_NO_MMAP");
        let decoded = decoded.unwrap();
        assert_eq!(decoded.backing(), Backing::Heap);
        let (a, b) = (mapped.matrix(), decoded.matrix());
        assert_eq!(a, b);
        for j in 0..a.ncols() {
            let (ra, va) = a.col(j);
            let (rb, vb) = b.col(j);
            assert_eq!(ra, rb);
            // Bit-level, not just numeric, equality.
            for (x, y) in va.iter().zip(vb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(mapped.csr_mirror(), decoded.csr_mirror());
        std::fs::remove_file(&path).unwrap();
    }
}
