//! Little-endian binary encode/decode helpers shared by the TCP transport
//! wire format and the end-of-run node reports. All multi-byte values are
//! little-endian; `f64` round-trips bit-exactly (`to_le_bytes` /
//! `from_le_bytes`), which the shm-vs-tcp equivalence guarantee depends on.

/// Append primitives to a byte buffer (little-endian).
pub fn put_u8(buf: &mut Vec<u8>, x: u8) {
    buf.push(x);
}

pub fn put_u16(buf: &mut Vec<u8>, x: u16) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    buf.reserve(8 * xs.len());
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Strict sequential reader over a byte slice; every accessor fails with a
/// message instead of panicking so callers can attach peer/rank context.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated message: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    pub fn f64s(&mut self, n: usize) -> Result<Vec<f64>, String> {
        let b = self.take(8 * n)?;
        let mut out = Vec::with_capacity(n);
        for c in b.chunks_exact(8) {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            out.push(f64::from_le_bytes(a));
        }
        Ok(out)
    }

    /// Everything was consumed (guards against trailing garbage / desync).
    pub fn finish(self) -> Result<(), String> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after message", self.remaining()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_primitives() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u16(&mut buf, 513);
        put_u32(&mut buf, 70_000);
        put_u64(&mut buf, u64::MAX - 3);
        put_f64(&mut buf, -0.125);
        put_f64s(&mut buf, &[1.5, f64::MIN_POSITIVE, -0.0]);
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 513);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.125f64).to_bits());
        let v = r.f64s(3).unwrap();
        assert_eq!(v[0], 1.5);
        assert_eq!(v[1], f64::MIN_POSITIVE);
        assert_eq!(v[2].to_bits(), (-0.0f64).to_bits());
        r.finish().unwrap();
    }

    #[test]
    fn underrun_and_trailing_detected() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 1);
        let mut r = ByteReader::new(&buf);
        assert!(r.u64().is_err());
        assert!(r.u32().is_ok());
        let mut r2 = ByteReader::new(&buf);
        assert!(r2.u16().is_ok());
        assert!(r2.finish().is_err());
    }
}
