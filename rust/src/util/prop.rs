//! Lightweight property-based testing driver (offline `proptest` stand-in).
//!
//! A property is a closure over a [`Gen`] (seeded PRNG wrapper with shaped
//! generators). The driver runs `cases` random cases; on failure it reports
//! the failing case's seed so the exact case can be replayed with
//! [`check_seeded`]. Shrinking is deliberately omitted — generators here
//! are sized explicitly, so failures are already small.

use crate::util::prng::Xoshiro256pp;

/// Shaped random-value generator handed to properties.
pub struct Gen {
    rng: Xoshiro256pp,
    /// Seed of this particular case (for replay diagnostics).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Self {
            rng: Xoshiro256pp::seed_from_u64(case_seed),
            case_seed,
        }
    }

    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    /// f64 uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// f64 with a wide log-uniform magnitude (sign-symmetric), good for
    /// stressing numeric code without overflowing.
    pub fn f64_reasonable(&mut self) -> f64 {
        let mag = 10f64.powf(self.rng.uniform(-3.0, 3.0));
        let sign = if self.rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
        sign * mag * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_f64() < 0.5
    }

    /// Vector of standard-normal entries.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Vector uniform in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    /// ±1 labels.
    pub fn labels(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| if self.rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect()
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`, panicking with the failing seed on
/// the first failure.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    // Derive per-case seeds from the property name so independent
    // properties explore independent streams, deterministically.
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (replay: check_seeded(\"{name}\", {seed}, ..)):\n  {msg}"
            );
        }
    }
}

/// Replay a single case by seed (used to debug a reported failure).
pub fn check_seeded(name: &str, seed: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property '{name}' failed on seeded replay {seed}:\n  {msg}");
    }
}

/// Assert helper: approximate equality with context for property messages.
pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol}, scale {scale})"))
    }
}

/// Assert helper: plain predicate with message.
pub fn ensure(cond: bool, what: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            count += 1;
            ensure(g.usize_in(0, 10) <= 10, "range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            ensure(x < 0.0, "impossible")
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        check("det", 5, |g| {
            first.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check("det", 5, |g| {
            second.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn ensure_close_scales() {
        assert!(ensure_close(1e6, 1e6 + 1.0, 1e-5, "big").is_ok());
        assert!(ensure_close(0.0, 1e-3, 1e-5, "small").is_err());
    }
}
