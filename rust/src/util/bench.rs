//! Micro-benchmark harness (offline replacement for `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and drive
//! this module: warmup, adaptive iteration count targeting a fixed measure
//! time, and median/p10/p90 reporting. Results can be appended to a CSV so
//! the §Perf log in EXPERIMENTS.md is regenerable.

use std::time::{Duration, Instant};

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
    /// Optional user-supplied throughput denominator (e.g. bytes or flops
    /// per iteration); enables a derived rate column.
    pub units_per_iter: Option<f64>,
}

impl Sample {
    pub fn rate(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.median_ns * 1e-9))
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    /// Number of measurement batches for the percentile estimate.
    pub batches: usize,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(600),
            batches: 20,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for end-to-end benches that run seconds per iteration.
    pub fn end_to_end() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(0),
            batches: 3,
            results: Vec::new(),
        }
    }

    /// Single-shot profile for multi-minute end-to-end suites (each "run"
    /// already aggregates many internal repetitions).
    pub fn once() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(0),
            batches: 1,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, printing a one-line summary. `units_per_iter` enables
    /// throughput reporting (see [`Sample::rate`]).
    pub fn run<T>(&mut self, name: &str, units_per_iter: Option<f64>, mut f: impl FnMut() -> T) {
        // Warmup and per-batch iteration calibration.
        let mut iters_per_batch = 1u64;
        if self.warmup > Duration::ZERO {
            let start = Instant::now();
            let mut n = 0u64;
            while start.elapsed() < self.warmup {
                black_box(f());
                n += 1;
            }
            let per = self.warmup.as_nanos() as f64 / n.max(1) as f64;
            let batch_budget = self.measure.as_nanos() as f64 / self.batches as f64;
            iters_per_batch = ((batch_budget / per).floor() as u64).max(1);
        }

        let mut per_iter_ns: Vec<f64> = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                black_box(f());
            }
            per_iter_ns.push(t.elapsed().as_nanos() as f64 / iters_per_batch as f64);
        }
        per_iter_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| -> f64 {
            let idx = ((per_iter_ns.len() - 1) as f64 * q).round() as usize;
            per_iter_ns[idx]
        };
        let sample = Sample {
            name: name.to_string(),
            iters: iters_per_batch * self.batches as u64,
            median_ns: pct(0.5),
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
            mean_ns: per_iter_ns.iter().sum::<f64>() / per_iter_ns.len() as f64,
            units_per_iter,
        };
        print_sample(&sample);
        self.results.push(sample);
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }

    /// Append all results to a CSV file (creating it with a header if new).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let new = !std::path::Path::new(path).exists();
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if new {
            writeln!(f, "name,iters,median_ns,p10_ns,p90_ns,mean_ns,rate")?;
        }
        for s in &self.results {
            writeln!(
                f,
                "{},{},{:.1},{:.1},{:.1},{:.1},{}",
                s.name,
                s.iters,
                s.median_ns,
                s.p10_ns,
                s.p90_ns,
                s.mean_ns,
                s.rate().map(|r| format!("{r:.3e}")).unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

fn print_sample(s: &Sample) {
    let fmt_ns = |ns: f64| -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    };
    let rate = match s.rate() {
        Some(r) if r >= 1e9 => format!("  [{:.2} G/s]", r / 1e9),
        Some(r) if r >= 1e6 => format!("  [{:.2} M/s]", r / 1e6),
        Some(r) => format!("  [{r:.2} /s]"),
        None => String::new(),
    };
    println!(
        "bench {:<48} median {:>10}  p10 {:>10}  p90 {:>10}  ({} iters){}",
        s.name,
        fmt_ns(s.median_ns),
        fmt_ns(s.p10_ns),
        fmt_ns(s.p90_ns),
        s.iters,
        rate
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            batches: 5,
            results: Vec::new(),
        };
        b.run("spin", None, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        let s = &b.results()[0];
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn rate_derivation() {
        let s = Sample {
            name: "x".into(),
            iters: 1,
            median_ns: 1e9,
            p10_ns: 1e9,
            p90_ns: 1e9,
            mean_ns: 1e9,
            units_per_iter: Some(2e6),
        };
        assert!((s.rate().unwrap() - 2e6).abs() < 1.0);
    }

    #[test]
    fn csv_append(){
        let dir = std::env::temp_dir().join("disco_bench_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("out.csv");
        let mut b = Bench::end_to_end();
        b.run("quick", Some(10.0), || 1 + 1);
        b.write_csv(path.to_str().unwrap()).unwrap();
        b.write_csv(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body.lines().count(), 3); // header + 2 appends
    }
}
