//! Minimal JSON support (emitter + recursive-descent parser).
//!
//! The offline environment has no `serde`/`serde_json`; the library needs
//! JSON in exactly two places — the AOT artifact `manifest.json` written by
//! `python/compile/aot.py`, and machine-readable metric dumps — so a small,
//! strict implementation is carried here. It supports the full JSON value
//! grammar except `\u` surrogate pairs beyond the BMP (sufficient for our
//! ASCII manifests), and rejects trailing garbage.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in sorted order (BTreeMap) so
/// emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["k"]` convenience: returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u escape"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Builder helpers for emitting objects without manual BTreeMap plumbing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(vals: Vec<Json>) -> Json {
    Json::Arr(vals)
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").as_arr().unwrap()[2], Json::Num(-2500.0));
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        // Emit and reparse — must be identical.
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_objects() {
        let v = Json::parse(r#"{"outer": {"inner": {"deep": [1,2,3]}}}"#).unwrap();
        let deep = v.get("outer").get("inner").get("deep");
        assert_eq!(deep.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        let v = Json::parse(r#""café λ""#).unwrap();
        assert_eq!(v.as_str(), Some("café λ"));
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Json::Null);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.5).to_string(), "3.5");
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("name", s("hvp")), ("dims", arr(vec![num(4.0), num(8.0)]))]);
        assert_eq!(v.to_string(), r#"{"dims":[4,8],"name":"hvp"}"#);
    }

    #[test]
    fn parse_whitespace_everywhere() {
        let v = Json::parse(" \n\t{ \"k\" :\t[ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("k").as_arr().unwrap().len(), 2);
    }
}
