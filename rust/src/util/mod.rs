//! Cross-cutting utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, timing, CSV output, micro-bench harness, and a
//! property-test driver. See DESIGN.md §7 for why these are in-tree.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod csv;
pub mod json;
pub mod prng;
pub mod prop;
pub mod timer;
