//! Wall-clock timing helpers and a simple hierarchical profiler used by the
//! coordinator to attribute time to compute / communication / idle phases.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A resettable stopwatch.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named time buckets; used for the per-phase breakdown the
/// paper's Figure 2 reasoning is about (compute vs communicate vs idle).
#[derive(Clone, Debug, Default)]
pub struct PhaseProfiler {
    buckets: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &str, d: Duration) {
        *self.buckets.entry(phase.to_string()).or_default() += d;
        *self.counts.entry(phase.to_string()).or_default() += 1;
    }

    /// Time a closure into a bucket.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.buckets.get(phase).copied().unwrap_or_default()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or_default()
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, Duration, u64)> {
        self.buckets
            .iter()
            .map(|(k, v)| (k.as_str(), *v, self.counts[k]))
    }

    pub fn merge(&mut self, other: &PhaseProfiler) {
        for (k, v) in &other.buckets {
            *self.buckets.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    /// Render a fixed-width summary table.
    pub fn report(&self) -> String {
        let total: Duration = self.buckets.values().sum();
        let mut out = String::new();
        out.push_str(&format!(
            "{:<24} {:>12} {:>10} {:>8}\n",
            "phase", "total", "calls", "share"
        ));
        for (k, v, c) in self.phases() {
            let share = if total.as_nanos() > 0 {
                100.0 * v.as_secs_f64() / total.as_secs_f64()
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<24} {:>10.3}ms {:>10} {:>7.1}%\n",
                k,
                v.as_secs_f64() * 1e3,
                c,
                share
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        let e1 = sw.reset();
        assert!(e1 >= Duration::from_millis(1));
        assert!(sw.elapsed() < e1 + Duration::from_millis(100));
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = PhaseProfiler::new();
        p.add("compute", Duration::from_millis(10));
        p.add("compute", Duration::from_millis(5));
        p.add("comm", Duration::from_millis(3));
        assert_eq!(p.total("compute"), Duration::from_millis(15));
        assert_eq!(p.count("compute"), 2);
        assert_eq!(p.count("comm"), 1);
        assert!(p.report().contains("compute"));
    }

    #[test]
    fn profiler_merge() {
        let mut a = PhaseProfiler::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseProfiler::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(3));
        assert_eq!(a.total("y"), Duration::from_millis(4));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseProfiler::new();
        let v = p.time("work", || 42);
        assert_eq!(v, 42);
        assert_eq!(p.count("work"), 1);
    }
}
