//! Declarative command-line flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, defaults, and auto-generated `--help`. Strict: unknown
//! flags are an error, so typos fail loudly in experiment scripts.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
enum Kind {
    /// Takes a value (string-typed; accessors convert).
    Value { default: Option<String> },
    /// Boolean presence flag.
    Switch,
}

#[derive(Clone, Debug)]
struct Spec {
    name: String,
    help: String,
    kind: Kind,
}

/// A flag schema plus parsed results.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a value flag with an optional default (None ⇒ required if read
    /// via `req_*`).
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            kind: Kind::Value {
                default: default.map(|s| s.to_string()),
            },
        });
        self
    }

    /// Declare a boolean switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            kind: Kind::Switch,
        });
        self
    }

    fn spec(&self, name: &str) -> Option<&Spec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// Parse a raw argv slice (excluding the program name). Returns the help
    /// text as Err if `--help` is present.
    pub fn parse(mut self, argv: &[String]) -> Result<Self, CliError> {
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(CliError(self.help_text()));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .spec(&name)
                    .ok_or_else(|| {
                        CliError(format!("unknown flag --{name}\n\n{}", self.help_text()))
                    })?
                    .clone();
                match spec.kind {
                    Kind::Switch => {
                        if inline.is_some() {
                            return Err(CliError(format!("switch --{name} takes no value")));
                        }
                        self.switches.insert(name, true);
                    }
                    Kind::Value { .. } => {
                        let v = match inline {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| CliError(format!("flag --{name} needs a value")))?
                                .clone(),
                        };
                        self.values.insert(name, v);
                    }
                }
            } else {
                self.positionals.push(a.clone());
            }
        }
        Ok(self)
    }

    /// Parse from the process environment.
    pub fn parse_env(self) -> Result<Self, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&argv)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.switches.get(name).unwrap_or(&false)
    }

    /// True when the flag was explicitly given on the command line
    /// (declared defaults don't count). Spec-backed CLIs use this to apply
    /// only the user's overrides on top of a loaded `--spec` file.
    pub fn provided(&self, name: &str) -> bool {
        self.values.contains_key(name) || self.switches.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        match self.spec(name) {
            Some(Spec {
                kind: Kind::Value { default: Some(d) },
                ..
            }) => Some(d.clone()),
            _ => None,
        }
    }

    pub fn req(&self, name: &str) -> Result<String, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing required flag --{name}")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.req(name)?;
        v.parse()
            .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.req(name)?;
        v.parse()
            .map_err(|_| CliError(format!("--{name}: expected float, got '{v}'")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.req(name)?;
        v.parse()
            .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'")))
    }

    /// Declare the standard transport flags shared by every binary that
    /// can run multi-process (`--transport`, `--rank`, `--world`,
    /// `--addr`, `--net-timeout`); parse them back with
    /// [`TransportCli::parse`].
    pub fn with_transport_flags(self) -> Self {
        self.opt(
            "transport",
            Some("shm"),
            "collective backend: shm (in-process thread simulation) | tcp (multi-process sockets)",
        )
        .opt("rank", Some("0"), "this process's rank, 0..world (tcp transport)")
        .opt("world", Some("1"), "total number of processes in the fleet (tcp transport)")
        .opt(
            "addr",
            Some("127.0.0.1:29500"),
            "rank-0 rendezvous address host:port (tcp transport)",
        )
        .opt(
            "net-timeout",
            Some("120"),
            "tcp deadline in seconds for the handshake and each collective socket op",
        )
    }

    pub fn help_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}", self.program, self.about);
        let _ = writeln!(out, "\nFLAGS:");
        for s in &self.specs {
            let meta = match &s.kind {
                Kind::Value { default: Some(d) } => format!(" <value> (default: {d})"),
                Kind::Value { default: None } => " <value>".to_string(),
                Kind::Switch => String::new(),
            };
            let _ = writeln!(out, "  --{}{}\n        {}", s.name, meta, s.help);
        }
        out
    }
}

/// Which collective backend a binary should run over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process thread cluster (the simulator; the default).
    Shm,
    /// Multi-process TCP mesh — this process is one rank of `world`.
    Tcp,
}

/// Parsed transport selection (see [`Args::with_transport_flags`]).
#[derive(Clone, Debug)]
pub struct TransportCli {
    pub kind: TransportKind,
    pub rank: usize,
    pub world: usize,
    pub addr: String,
    pub timeout_secs: f64,
}

impl TransportCli {
    pub fn parse(args: &Args) -> Result<TransportCli, CliError> {
        let kind = match args.req("transport")?.as_str() {
            "shm" => TransportKind::Shm,
            "tcp" => TransportKind::Tcp,
            other => {
                return Err(CliError(format!(
                    "unknown transport '{other}' (expected shm | tcp)"
                )))
            }
        };
        let rank = args.get_usize("rank")?;
        let world = args.get_usize("world")?;
        let addr = args.req("addr")?;
        let timeout_secs = args.get_f64("net-timeout")?;
        if kind == TransportKind::Tcp {
            if world == 0 {
                return Err(CliError("--world must be at least 1".into()));
            }
            if rank >= world {
                return Err(CliError(format!(
                    "--rank {rank} out of range for --world {world}"
                )));
            }
            if !(timeout_secs.is_finite() && timeout_secs > 0.0) {
                return Err(CliError("--net-timeout must be a positive number".into()));
            }
        }
        Ok(TransportCli { kind, rank, world, addr, timeout_secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn schema() -> Args {
        Args::new("disco", "test")
            .opt("dataset", Some("news20s"), "dataset name")
            .opt("tau", Some("100"), "preconditioner samples")
            .opt("lambda", None, "regularization")
            .switch("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let a = schema().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("dataset").unwrap(), "news20s");
        assert_eq!(a.get_usize("tau").unwrap(), 100);
        assert!(!a.flag("verbose"));
        assert!(a.get("lambda").is_none());
    }

    #[test]
    fn explicit_values_and_eq_syntax() {
        let a = schema()
            .parse(&argv(&["--dataset", "rcv1s", "--tau=200", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("dataset").unwrap(), "rcv1s");
        assert_eq!(a.get_usize("tau").unwrap(), 200);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(schema().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(schema().parse(&argv(&["--tau"])).is_err());
    }

    #[test]
    fn provided_distinguishes_defaults_from_explicit() {
        let a = schema().parse(&argv(&["--tau", "50", "--verbose"])).unwrap();
        assert!(a.provided("tau"));
        assert!(a.provided("verbose"));
        assert!(!a.provided("dataset"), "default must not count as provided");
        assert!(!a.provided("lambda"));
    }

    #[test]
    fn positionals_collected() {
        let a = schema().parse(&argv(&["run", "--tau", "50", "fig3"])).unwrap();
        assert_eq!(a.positionals(), &["run".to_string(), "fig3".to_string()]);
    }

    #[test]
    fn type_errors_reported() {
        let a = schema().parse(&argv(&["--tau", "abc"])).unwrap();
        assert!(a.get_usize("tau").is_err());
    }

    #[test]
    fn help_lists_flags() {
        let err = schema().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.0.contains("--dataset"));
        assert!(err.0.contains("--verbose"));
    }

    #[test]
    fn transport_flags_default_to_shm() {
        let a = Args::new("t", "t")
            .with_transport_flags()
            .parse(&argv(&[]))
            .unwrap();
        let t = TransportCli::parse(&a).unwrap();
        assert_eq!(t.kind, TransportKind::Shm);
        assert_eq!(t.rank, 0);
        assert_eq!(t.world, 1);
    }

    #[test]
    fn transport_flags_parse_tcp() {
        let a = Args::new("t", "t")
            .with_transport_flags()
            .parse(&argv(&[
                "--transport",
                "tcp",
                "--rank",
                "2",
                "--world",
                "3",
                "--addr",
                "127.0.0.1:4100",
                "--net-timeout",
                "5",
            ]))
            .unwrap();
        let t = TransportCli::parse(&a).unwrap();
        assert_eq!(t.kind, TransportKind::Tcp);
        assert_eq!(t.rank, 2);
        assert_eq!(t.world, 3);
        assert_eq!(t.addr, "127.0.0.1:4100");
        assert!((t.timeout_secs - 5.0).abs() < 1e-12);
    }

    #[test]
    fn transport_flags_reject_bad_rank_and_kind() {
        let a = Args::new("t", "t")
            .with_transport_flags()
            .parse(&argv(&["--transport", "tcp", "--rank", "3", "--world", "3"]))
            .unwrap();
        assert!(TransportCli::parse(&a).is_err());
        let a = Args::new("t", "t")
            .with_transport_flags()
            .parse(&argv(&["--transport", "carrier-pigeon"]))
            .unwrap();
        assert!(TransportCli::parse(&a).is_err());
    }
}
