//! The rule set: each of the repo's written-but-unchecked determinism
//! invariants as a machine-checked rule over the lexed/parsed sources.
//!
//! Rules are scoped by path (relative to the walk root, `/`-separated,
//! e.g. `algorithms/disco_f.rs`), skip `#[cfg(test)]`/`#[cfg(loom)]`
//! items, and honor `// lint: allow(<rule>)` suppressions (same line or
//! the line above; `allow-file` for a whole file). The runtime
//! counterpart `schedule-divergence` is enforced by
//! [`Checked`](crate::net::Checked), not here — it is listed in
//! [`RULES`](crate::lint::RULES) for documentation symmetry.

use std::collections::{BTreeMap, BTreeSet};

use crate::lint::lexer::{Allows, Tok, TokKind};
use crate::lint::parse::FileInfo;
use crate::lint::Violation;

/// One lexed + parsed source file, path-normalized.
pub struct SourceFile {
    pub path: String,
    pub toks: Vec<Tok>,
    pub allows: Allows,
    pub info: FileInfo,
}

impl SourceFile {
    fn in_dir(&self, dir: &str) -> bool {
        self.path.starts_with(dir)
    }
}

/// Fns whose every call site sits inside a `.compute*` argument span (or
/// inside another such fn): work in their bodies is priced through the
/// compute hooks even though the tokens sit outside the closure. Built
/// crate-wide by name (a deliberate approximation: free functions and
/// methods sharing a name pool their call sites, which only ever widens
/// the *non*-exempt set).
pub struct CostedFns(BTreeSet<String>);

pub fn build_costed_fns(files: &[SourceFile]) -> CostedFns {
    // name -> call sites as (costed-span?, enclosing fn name)
    let mut sites: BTreeMap<&str, Vec<(bool, Option<&str>)>> = BTreeMap::new();
    let mut defined: BTreeSet<&str> = BTreeSet::new();
    for f in files {
        for fun in &f.info.fns {
            defined.insert(fun.name.as_str());
        }
        for call in &f.info.calls {
            let encl = f.info.enclosing_fn(call.idx).map(|x| x.name.as_str());
            sites
                .entry(call.name.as_str())
                .or_default()
                .push((f.info.in_compute(call.idx), encl));
        }
    }
    let mut costed: BTreeSet<String> = BTreeSet::new();
    // Fixpoint: transitively costed callees converge in a few rounds;
    // cycles conservatively stay uncosted.
    for _ in 0..10 {
        let mut changed = false;
        for &name in &defined {
            if costed.contains(name) {
                continue;
            }
            let Some(calls) = sites.get(name) else { continue };
            if calls.is_empty() {
                continue;
            }
            let all_costed = calls.iter().all(|(in_compute, encl)| {
                *in_compute || encl.is_some_and(|e| costed.contains(e))
            });
            if all_costed {
                costed.insert(name.to_string());
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    CostedFns(costed)
}

/// Apply every rule to one file. `costed` comes from
/// [`build_costed_fns`] over the whole walked set.
pub fn check_file(f: &SourceFile, costed: &CostedFns) -> Vec<Violation> {
    let mut out = Vec::new();
    wall_clock(f, &mut out);
    transport_unwrap(f, &mut out);
    hash_iter(f, &mut out);
    unseeded_rng(f, &mut out);
    f32_literal(f, &mut out);
    uncosted_compute(f, costed, &mut out);
    raw_print(f, &mut out);
    unbounded_read(f, &mut out);
    unawaited_handle(f, &mut out);
    out.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    out
}

/// Shared emit path: test spans and allow-directives filter here, so
/// every rule body stays a pure detector.
fn emit(f: &SourceFile, idx: usize, rule: &'static str, message: String, out: &mut Vec<Violation>) {
    if f.info.in_test(idx) {
        return;
    }
    let t = &f.toks[idx];
    if f.allows.allowed(rule, t.line) {
        return;
    }
    out.push(Violation {
        path: f.path.clone(),
        line: t.line,
        col: t.col,
        rule,
        message,
    });
}

fn seq_ident2(toks: &[Tok], i: usize, a: &str, b: &str) -> bool {
    // `a::b` — the lexer splits `::` into two ':' puncts.
    toks[i].is_ident(a)
        && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
        && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        && toks.get(i + 3).is_some_and(|t| t.is_ident(b))
}

/// `wall-clock`: `Instant::now()` / `SystemTime::now()` outside the
/// transport/chaos whitelist. Wall time feeds the *measured* compute
/// model and transport deadlines only; anywhere else it breaks the
/// modeled clock's bit-determinism.
fn wall_clock(f: &SourceFile, out: &mut Vec<Violation>) {
    let whitelisted = f.in_dir("net/transport/")
        || f.path == "net/cluster.rs"
        || f.path == "util/timer.rs"
        || f.path == "util/bench.rs"
        || f.in_dir("runtime/")
        || f.in_dir("bin/")
        || f.in_dir("lint/")
        || f.path == "main.rs";
    if whitelisted {
        return;
    }
    for i in 0..f.toks.len() {
        if seq_ident2(&f.toks, i, "Instant", "now") || seq_ident2(&f.toks, i, "SystemTime", "now")
        {
            emit(
                f,
                i,
                "wall-clock",
                format!(
                    "{}::now() outside the transport/chaos whitelist — wall time \
                     breaks modeled-clock determinism",
                    f.toks[i].text
                ),
                out,
            );
        }
    }
}

/// `transport-unwrap`: `.unwrap()` / `.expect(` on the socket paths under
/// `net/transport/`. A panic there tears a peer down without the
/// `fail()` / `FrameError` contract, so the fleet sees a hang instead of
/// a named failure.
fn transport_unwrap(f: &SourceFile, out: &mut Vec<Violation>) {
    if !f.in_dir("net/transport/") {
        return;
    }
    for i in 1..f.toks.len() {
        let t = &f.toks[i];
        let is_target = t.is_ident("unwrap") || t.is_ident("expect");
        if is_target
            && f.toks[i - 1].is_punct('.')
            && f.toks.get(i + 1).is_some_and(|t| t.is_punct('('))
        {
            emit(
                f,
                i,
                "transport-unwrap",
                format!(
                    ".{}() on a transport path — map the failure through fail()/\
                     FrameError so peers see `cluster node failed` instead of a hang",
                    t.text
                ),
                out,
            );
        }
    }
}

/// `hash-iter`: `HashMap`/`HashSet` in numeric or pricing code. Their
/// iteration order is randomized per process, so any fold, serialization,
/// or schedule derived from it diverges across ranks and runs. (Usage is
/// flagged, not just iteration: a hash container in deterministic code is
/// one `for` loop away from a bit-diff.) `runtime/` is exempt — the XLA
/// boundary never feeds the priced spine.
fn hash_iter(f: &SourceFile, out: &mut Vec<Violation>) {
    if f.in_dir("runtime/") {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if t.is_ident("HashMap") || t.is_ident("HashSet") {
            emit(
                f,
                i,
                "hash-iter",
                format!(
                    "{} iterates in nondeterministic order — use BTreeMap/BTreeSet \
                     or a rank-indexed Vec in numeric/pricing code",
                    t.text
                ),
                out,
            );
        }
    }
}

/// `unseeded-rng`: ambient randomness (`thread_rng`, `rand::random`,
/// entropy-seeded constructors). Every random draw must flow through the
/// seeded `Xoshiro256pp` streams or repeated runs stop being comparable.
fn unseeded_rng(f: &SourceFile, out: &mut Vec<Violation>) {
    const BANNED: &[&str] = &["thread_rng", "from_entropy", "OsRng", "StdRng", "SmallRng"];
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        let hit = (t.kind == TokKind::Ident && BANNED.contains(&t.text.as_str()))
            || seq_ident2(&f.toks, i, "rand", "random");
        if hit {
            emit(
                f,
                i,
                "unseeded-rng",
                format!(
                    "{} is ambient RNG — all randomness must flow through the seeded \
                     Xoshiro256pp streams",
                    t.text
                ),
                out,
            );
        }
    }
}

/// `f32-literal`: `f32` anywhere in the f64 numeric spine. Accumulating
/// or truncating through f32 silently changes bits between code paths;
/// `runtime/` (the XLA boundary, which is f32 by design) is exempt.
fn f32_literal(f: &SourceFile, out: &mut Vec<Violation>) {
    if f.in_dir("runtime/") {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        let hit = t.is_ident("f32")
            || matches!(&t.kind, TokKind::Number { suffix, .. } if suffix == "f32");
        if hit {
            emit(
                f,
                i,
                "f32-literal",
                "f32 in the f64 numeric spine — the paper's accounting and the \
                 bit-identity guarantee are f64-only (runtime/ is the f32 boundary)"
                    .to_string(),
                out,
            );
        }
    }
}

/// `uncosted-compute`: a floating-point loop in `algorithms/` that is not
/// priced. Legitimate loops either live inside a `.compute*` closure
/// (priced directly), mention `ctx` (communication/driver loops — their
/// work *is* collectives and costed segments), or sit in a fn reachable
/// only from compute spans (the call-graph approximation). Anything else
/// is numeric work the modeled clock never sees — exactly the Fig. 2
/// attribution hole the cost model exists to prevent.
fn uncosted_compute(f: &SourceFile, costed: &CostedFns, out: &mut Vec<Violation>) {
    if !f.in_dir("algorithms/") {
        return;
    }
    for l in &f.info.loops {
        if f.info.in_compute(l.kw) {
            continue;
        }
        let body = &f.toks[l.body.0..=l.body.1];
        let has_float = body
            .iter()
            .any(|t| matches!(&t.kind, TokKind::Number { float: true, .. }));
        if !has_float {
            continue;
        }
        let mentions_ctx = body.iter().any(|t| t.is_ident("ctx"));
        if mentions_ctx {
            continue;
        }
        if let Some(encl) = f.info.enclosing_fn(l.kw) {
            if costed.0.contains(&encl.name) {
                continue;
            }
        }
        emit(
            f,
            l.kw,
            "uncosted-compute",
            "floating-point loop outside ctx.compute*() — this work is invisible \
             to the modeled clock (price it via compute_costed, or justify with an \
             allow comment)"
                .to_string(),
            out,
        );
    }
}

/// `unbounded-read`: whole-input materialization (`read_to_string`,
/// `read_to_end`, `lines().collect()`) in the data-path library code
/// (`data/`, `store/`). The out-of-core contract is that the global
/// matrix is never resident — loaders stream through a reused
/// `read_line` buffer or a validated fixed-size section. Intentionally
/// bounded reads (a KB-scale manifest, one checksummed shard) carry an
/// allow comment.
fn unbounded_read(f: &SourceFile, out: &mut Vec<Violation>) {
    if !(f.in_dir("data/") || f.in_dir("store/")) {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        let called = f.toks.get(i + 1).is_some_and(|n| n.is_punct('('));
        let is_def = i > 0 && f.toks[i - 1].is_ident("fn");
        if (t.is_ident("read_to_string") || t.is_ident("read_to_end")) && called && !is_def {
            emit(
                f,
                i,
                "unbounded-read",
                format!(
                    "{}() materializes the whole input — the data path streams \
                     (read_line over a reused buffer); justify a bounded read with \
                     an allow comment",
                    t.text
                ),
                out,
            );
        }
        // `lines().collect()` — one heap String per line of the input.
        if t.is_ident("lines")
            && called
            && f.toks.get(i + 2).is_some_and(|n| n.is_punct(')'))
            && f.toks.get(i + 3).is_some_and(|n| n.is_punct('.'))
            && f.toks.get(i + 4).is_some_and(|n| n.is_ident("collect"))
        {
            emit(
                f,
                i,
                "unbounded-read",
                "lines().collect() materializes every line — stream through one \
                 reused read_line buffer instead"
                    .to_string(),
                out,
            );
        }
    }
}

/// `unawaited-handle`: a split-phase `.start_*()` call in `algorithms/`
/// whose enclosing fn never mentions `wait_collective` afterwards. Every
/// started collective must be waited — the completion time is *priced at
/// the wait*, and on TCP the wire round itself only runs there, so a
/// dropped handle undercounts the modeled clock and desyncs the
/// schedule that [`Checked`](crate::net::Checked) verifies. (Token-level
/// approximation: the wait must appear later in the same fn body; a
/// handle legitimately returned to a caller carries an allow comment.)
fn unawaited_handle(f: &SourceFile, out: &mut Vec<Violation>) {
    if !f.in_dir("algorithms/") {
        return;
    }
    for i in 1..f.toks.len() {
        let t = &f.toks[i];
        let is_start = t.kind == TokKind::Ident && t.text.starts_with("start_");
        if !(is_start
            && f.toks[i - 1].is_punct('.')
            && f.toks.get(i + 1).is_some_and(|n| n.is_punct('(')))
        {
            continue;
        }
        let end = f.info.enclosing_fn(i).map_or(f.toks.len() - 1, |fun| fun.body.1);
        let waited = f.toks[i + 1..=end]
            .iter()
            .any(|t| t.is_ident("wait_collective"));
        if !waited {
            emit(
                f,
                i,
                "unawaited-handle",
                format!(
                    "{}() handle never reaches wait_collective in this fn — split-phase \
                     completion is priced at the wait, so a dropped handle undercounts \
                     the modeled clock (wait it, or justify handing it to the caller \
                     with an allow comment)",
                    t.text
                ),
                out,
            );
        }
    }
}

/// `raw-print`: `println!`/`eprintln!`/`print!`/`eprint!` in library code.
/// The binaries' stdout is machine-read (CI greps it, `--events` summaries
/// and figure previews flow through it), so stray prints from deep inside
/// the library corrupt those surfaces and differ per rank. Printing is
/// confined to the CLI entrypoints (`bin/`, `main.rs`), the obs sinks
/// (`obs/`), and the bench harness; operator-facing progress lines
/// elsewhere carry an explicit allow.
fn raw_print(f: &SourceFile, out: &mut Vec<Violation>) {
    let whitelisted = f.in_dir("bin/")
        || f.path == "main.rs"
        || f.in_dir("obs/")
        || f.path == "util/bench.rs";
    if whitelisted {
        return;
    }
    const MACROS: &[&str] = &["println", "eprintln", "print", "eprint"];
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        let hit = t.kind == TokKind::Ident
            && MACROS.contains(&t.text.as_str())
            && f.toks.get(i + 1).is_some_and(|n| n.is_punct('!'));
        if hit {
            emit(
                f,
                i,
                "raw-print",
                format!(
                    "{}! in library code — stdout/stderr are machine-read surfaces; \
                     route output through the CLI layer or an obs sink, or justify \
                     an operator-facing line with an allow comment",
                    t.text
                ),
                out,
            );
        }
    }
}
