//! disco-lint: the determinism & collective-schedule analysis pass.
//!
//! The repo's core guarantee — a seeded run is bit-identical across the
//! simulator, the shm thread cluster, and a real TCP fleet — is easy to
//! break with one innocent-looking line: an `Instant::now()` in an
//! algorithm, a `HashMap` iteration feeding a serializer, an unwrap on a
//! socket path that turns a peer failure into a silent hang. This module
//! is a small static analyzer (hand-rolled lexer + span pass; the crate
//! is dependency-free, so no `syn`) that enforces those invariants as
//! CI-fatal rules, plus the documentation anchor for the runtime
//! `schedule-divergence` checker ([`crate::net::Checked`]).
//!
//! Rules (static):
//!
//! * `wall-clock` — `Instant::now()`/`SystemTime::now()` outside the
//!   transport/chaos whitelist.
//! * `transport-unwrap` — `.unwrap()`/`.expect()` under `net/transport/`.
//! * `hash-iter` — `HashMap`/`HashSet` in numeric/pricing code.
//! * `unseeded-rng` — `thread_rng`/`rand::random`/entropy-seeded RNGs.
//! * `f32-literal` — `f32` in the f64 numeric spine.
//! * `uncosted-compute` — floating-point loops in `algorithms/` not
//!   reachable through `ctx.compute*` (call-graph approximation).
//! * `raw-print` — `println!`/`eprintln!` in library code outside the CLI
//!   entrypoints, the obs sinks, and the bench harness.
//! * `unbounded-read` — `read_to_string`/`read_to_end`/`lines().collect()`
//!   in `data/`/`store/` library code (the out-of-core data path must
//!   stream; bounded reads carry an allow comment).
//! * `unawaited-handle` — a split-phase `.start_*()` in `algorithms/`
//!   with no `wait_collective` later in the same fn (completion is
//!   priced at the wait; a dropped handle undercounts the clock).
//!
//! Runtime (documented here, enforced by [`crate::net::Checked`]):
//!
//! * `schedule-divergence` — ranks issuing different collective
//!   sequences, caught *before* the mismatched collective deadlocks.
//!
//! Suppression: `// lint: allow(<rule>) — why` on the offending line or
//! the line above; `// lint: allow-file(<rule>)` anywhere in a file for
//! the whole file. Items under `#[test]`/`#[cfg(test)]`/`#[cfg(loom)]`
//! are exempt from all rules.
//!
//! Run it as `cargo run --bin disco-lint` (CI does, and fails on any
//! violation).

pub mod lexer;
pub mod parse;
pub mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

use rules::SourceFile;

/// One rule hit: `path:line:col: rule: message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Path relative to the walk root, `/`-separated.
    pub path: String,
    pub line: usize,
    pub col: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: {}: {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }
}

/// The rule table (`disco-lint --list-rules`). `schedule-divergence` is
/// the runtime half — listed so the tool documents the full contract.
pub const RULES: &[(&str, &str)] = &[
    (
        "wall-clock",
        "Instant::now()/SystemTime::now() outside net/transport, cluster, timer/bench, runtime, bin",
    ),
    (
        "transport-unwrap",
        "unwrap()/expect() on net/transport/ socket paths (must map to fail()/FrameError)",
    ),
    (
        "hash-iter",
        "HashMap/HashSet in numeric or pricing code (nondeterministic iteration order)",
    ),
    (
        "unseeded-rng",
        "thread_rng/rand::random/entropy-seeded RNGs (all draws must use the seeded streams)",
    ),
    (
        "f32-literal",
        "f32 types or literals in the f64 numeric spine (runtime/ is the f32 boundary)",
    ),
    (
        "uncosted-compute",
        "floating-point loop in algorithms/ not priced through ctx.compute* (call-graph approx.)",
    ),
    (
        "raw-print",
        "println!/eprintln!/print!/eprint! outside bin/, main.rs, obs/ sinks, and util/bench.rs (stray prints corrupt machine-read stdout)",
    ),
    (
        "unbounded-read",
        "read_to_string/read_to_end/lines().collect() in data//store/ library code (the out-of-core data path streams)",
    ),
    (
        "unawaited-handle",
        "split-phase .start_*() in algorithms/ with no later wait_collective in the same fn (completion is priced at the wait)",
    ),
    (
        "schedule-divergence",
        "runtime: ranks issued different collective sequences (enforced by net::Checked, DISCO_CHECKED=1)",
    ),
];

/// Lex + parse one source buffer into the form the rules consume.
/// `path` must already be root-relative and `/`-separated.
pub fn load_source(path: &str, src: &str) -> SourceFile {
    let lexed = lexer::lex(src);
    let info = parse::parse(&lexed.toks);
    SourceFile {
        path: path.to_string(),
        toks: lexed.toks,
        allows: lexed.allows,
        info,
    }
}

/// Walk `root` for `.rs` files (sorted, so output order is deterministic)
/// and return every violation. I/O errors surface as `Err` rather than
/// silently shrinking the tree being checked.
pub fn lint_tree(root: &Path) -> Result<Vec<Violation>, String> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let src = std::fs::read_to_string(p)
            .map_err(|e| format!("read {}: {e}", p.display()))?;
        files.push(load_source(&rel_path(root, p), &src));
    }
    Ok(lint_files(&files))
}

/// Rule pass over pre-loaded sources (the tests feed fixtures directly).
pub fn lint_files(files: &[SourceFile]) -> Vec<Violation> {
    let costed = rules::build_costed_fns(files);
    let mut out = Vec::new();
    for f in files {
        out.extend(rules::check_file(f, &costed));
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|ext| ext == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_formats_as_grep_line() {
        let v = Violation {
            path: "algorithms/x.rs".into(),
            line: 3,
            col: 7,
            rule: "wall-clock",
            message: "nope".into(),
        };
        assert_eq!(v.to_string(), "algorithms/x.rs:3:7: wall-clock: nope");
    }

    #[test]
    fn rules_table_names_are_unique_and_kebab() {
        let mut seen = std::collections::BTreeSet::new();
        for (name, doc) in RULES {
            assert!(seen.insert(*name), "duplicate rule {name}");
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c == '-' || c.is_ascii_digit()),
                "rule {name} is not kebab-case"
            );
            assert!(!doc.is_empty());
        }
    }

    #[test]
    fn clean_source_has_no_violations() {
        let f = load_source(
            "algorithms/clean.rs",
            "pub fn grad(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>() * 0.5\n}\n",
        );
        assert!(lint_files(&[f]).is_empty());
    }

    #[test]
    fn allow_comment_suppresses_on_same_and_next_line() {
        let src = "\
fn t() {
    // lint: allow(hash-iter) — tracked set, never iterated
    let x: HashMap<u32, u32> = HashMap::new();
    let _ = x;
}
";
        let f = load_source("algorithms/a.rs", src);
        // Directive covers its own line and the next — the second
        // `HashMap` (same line 3) is covered too.
        assert!(lint_files(&[f]).is_empty());
        let src_noallow =
            src.replace("// lint: allow(hash-iter) — tracked set, never iterated", "");
        let f = load_source("algorithms/a.rs", &src_noallow);
        assert_eq!(lint_files(&[f]).len(), 2);
    }

    #[test]
    fn test_items_are_exempt() {
        let src = "\
#[cfg(test)]
mod tests {
    fn helper() {
        let _ = std::time::Instant::now();
    }
}
";
        let f = load_source("algorithms/a.rs", src);
        assert!(lint_files(&[f]).is_empty());
    }

    #[test]
    fn cfg_not_test_is_still_linted() {
        let src = "\
#[cfg(not(test))]
fn prod() {
    let _ = Instant::now();
}
";
        let f = load_source("algorithms/a.rs", src);
        let v = lint_files(&[f]);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "wall-clock");
    }

    #[test]
    fn costed_fn_exempts_its_loops() {
        // `inner_kernel` is only ever called inside a compute span, so its
        // float loop is priced and must not flag; `rogue` is called from
        // plain driver code and must flag.
        let src = "\
fn inner_kernel(xs: &mut [f64]) {
    for x in xs.iter_mut() {
        *x *= 0.5;
    }
}
fn rogue(xs: &mut [f64]) {
    for x in xs.iter_mut() {
        *x *= 0.5;
    }
}
fn driver(ctx: &mut Ctx, xs: &mut [f64]) {
    ctx.compute_costed(1.0, |_| inner_kernel(xs));
    rogue(xs);
}
";
        let f = load_source("algorithms/a.rs", src);
        let v = lint_files(&[f]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "uncosted-compute");
        assert_eq!(v[0].line, 7);
    }
}
