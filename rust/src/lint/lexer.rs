//! A minimal Rust lexer for `disco-lint`.
//!
//! The crate deliberately carries zero dependencies, so there is no `syn`
//! here: this is a hand-rolled token scanner that understands exactly as
//! much Rust surface syntax as the rules need — comments (line, nested
//! block), string/char/byte/raw-string literals, lifetimes, numeric
//! literals with suffixes and exponents, identifiers, and single-char
//! punctuation. Everything the rules match on (identifier sequences,
//! float literals, brace structure) survives; everything else is noise
//! the rules ignore.
//!
//! Line comments are additionally scanned for suppression directives:
//!
//! ```text
//! // lint: allow(rule-name)            — this line and the next
//! // lint: allow(rule-a, rule-b)       — several rules at once
//! // lint: allow-file(rule-name)       — the whole file
//! ```

use std::collections::{BTreeMap, BTreeSet};

/// Token classes. Keywords are `Ident`s — the parser layer decides what
/// is a keyword by spelling, which is all the rules need.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    /// Numeric literal. `float` is true for `1.0`, `1.`, `1e3`, `1f64` …;
    /// `suffix` is the trailing type suffix (`"f32"`, `"u64"`, `""`).
    Number { float: bool, suffix: String },
    /// Any string, char, or byte literal (contents irrelevant to rules).
    Str,
    /// One punctuation character (`::` arrives as two `Punct(':')`).
    Punct(char),
}

/// One token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
    pub col: usize,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }
}

/// Per-file suppression state collected from `// lint:` directives.
#[derive(Debug, Default)]
pub struct Allows {
    file: BTreeSet<String>,
    lines: BTreeMap<usize, BTreeSet<String>>,
}

impl Allows {
    /// Is `rule` suppressed at `line` (same-line or preceding-line
    /// comment, or a file-wide directive)?
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.file.contains(rule)
            || self
                .lines
                .get(&line)
                .is_some_and(|rules| rules.contains(rule))
    }

    fn add_line(&mut self, line: usize, rule: &str) {
        // The directive covers its own line (trailing comment) and the
        // next (comment above the flagged code).
        self.lines.entry(line).or_default().insert(rule.to_string());
        self.lines.entry(line + 1).or_default().insert(rule.to_string());
    }

    fn parse_comment(&mut self, line: usize, text: &str) {
        let Some(pos) = text.find("lint:") else { return };
        let rest = text[pos + 5..].trim_start();
        let (file_wide, rest) = if let Some(r) = rest.strip_prefix("allow-file(") {
            (true, r)
        } else if let Some(r) = rest.strip_prefix("allow(") {
            (false, r)
        } else {
            return;
        };
        let Some(close) = rest.find(')') else { return };
        for rule in rest[..close].split(',') {
            let rule = rule.trim();
            if rule.is_empty() {
                continue;
            }
            if file_wide {
                self.file.insert(rule.to_string());
            } else {
                self.add_line(line, rule);
            }
        }
    }
}

/// Lexed file: the token stream plus the suppression directives.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Allows,
}

struct Cursor<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Cursor<'a> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.src.get(self.pos).copied()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Tokenize `src`. Unterminated constructs (possible only on a file that
/// `rustc` would reject anyway) terminate at end of input rather than
/// erroring: a linter must never be the tool that fails first.
pub fn lex(src: &str) -> Lexed {
    let mut c = Cursor { src: src.as_bytes(), pos: 0, line: 1, col: 1 };
    let mut toks = Vec::new();
    let mut allows = Allows::default();

    while let Some(b) = c.peek(0) {
        let (line, col) = (c.line, c.col);
        match b {
            b' ' | b'\t' | b'\r' | b'\n' => {
                c.bump();
            }
            b'/' if c.peek(1) == Some(b'/') => {
                let start = c.pos + 2;
                while c.peek(0).is_some_and(|b| b != b'\n') {
                    c.bump();
                }
                let text = std::str::from_utf8(&c.src[start..c.pos]).unwrap_or("");
                allows.parse_comment(line, text);
            }
            b'/' if c.peek(1) == Some(b'*') => {
                c.bump();
                c.bump();
                let mut depth = 1usize;
                while depth > 0 {
                    match (c.peek(0), c.peek(1)) {
                        (Some(b'/'), Some(b'*')) => {
                            c.bump();
                            c.bump();
                            depth += 1;
                        }
                        (Some(b'*'), Some(b'/')) => {
                            c.bump();
                            c.bump();
                            depth -= 1;
                        }
                        (Some(_), _) => {
                            c.bump();
                        }
                        (None, _) => break,
                    }
                }
            }
            b'"' => {
                lex_cooked_string(&mut c);
                toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
            }
            b'\'' => {
                lex_quote(&mut c, &mut toks, line, col);
            }
            _ if b.is_ascii_digit() => {
                let tok = lex_number(&mut c, line, col);
                toks.push(tok);
            }
            _ if is_ident_start(b) => {
                let start = c.pos;
                while c.peek(0).is_some_and(is_ident_continue) {
                    c.bump();
                }
                let text = std::str::from_utf8(&c.src[start..c.pos]).unwrap_or("").to_string();
                // String prefixes: r"…", r#"…"#, b"…", br#"…"#.
                let raw_follows = matches!(c.peek(0), Some(b'"') | Some(b'#'));
                if raw_follows && matches!(text.as_str(), "r" | "br" | "rb") {
                    lex_raw_string(&mut c);
                    toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                } else if c.peek(0) == Some(b'"') && text == "b" {
                    c.bump();
                    lex_cooked_string_tail(&mut c);
                    toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                } else {
                    toks.push(Tok { kind: TokKind::Ident, text, line, col });
                }
            }
            _ => {
                c.bump();
                toks.push(Tok {
                    kind: TokKind::Punct(b as char),
                    text: (b as char).to_string(),
                    line,
                    col,
                });
            }
        }
    }
    Lexed { toks, allows }
}

/// Consume a `"…"` literal starting at the opening quote.
fn lex_cooked_string(c: &mut Cursor) {
    c.bump(); // opening quote
    lex_cooked_string_tail(c);
}

/// Consume the remainder of a `"…"` literal after the opening quote.
fn lex_cooked_string_tail(c: &mut Cursor) {
    while let Some(b) = c.peek(0) {
        match b {
            b'\\' => {
                c.bump();
                c.bump();
            }
            b'"' => {
                c.bump();
                return;
            }
            _ => {
                c.bump();
            }
        }
    }
}

/// Consume `r"…"` / `r#"…"#` (any `#` count); cursor sits after the
/// `r`/`br` prefix.
fn lex_raw_string(c: &mut Cursor) {
    let mut hashes = 0usize;
    while c.peek(0) == Some(b'#') {
        c.bump();
        hashes += 1;
    }
    if c.peek(0) != Some(b'"') {
        return; // `r#` in attribute position (raw ident) — not a string
    }
    c.bump();
    'scan: while let Some(b) = c.bump() {
        if b == b'"' {
            for i in 0..hashes {
                if c.peek(i) != Some(b'#') {
                    continue 'scan;
                }
            }
            for _ in 0..hashes {
                c.bump();
            }
            return;
        }
    }
}

/// Disambiguate `'a'` (char literal) from `'a` (lifetime) at a `'`.
fn lex_quote(c: &mut Cursor, toks: &mut Vec<Tok>, line: usize, col: usize) {
    c.bump(); // the quote
    match c.peek(0) {
        Some(b'\\') => {
            // Escaped char literal: skip the escape, then run to the
            // closing quote (covers \n, \', \u{…}).
            c.bump();
            c.bump();
            while c.peek(0).is_some_and(|b| b != b'\'') {
                c.bump();
            }
            c.bump();
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
        }
        Some(b) if c.peek(1) == Some(b'\'') => {
            // 'x' — one char then the closing quote.
            let _ = b;
            c.bump();
            c.bump();
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
        }
        Some(b) if is_ident_start(b) => {
            let start = c.pos;
            while c.peek(0).is_some_and(is_ident_continue) {
                c.bump();
            }
            let text = std::str::from_utf8(&c.src[start..c.pos]).unwrap_or("").to_string();
            toks.push(Tok { kind: TokKind::Lifetime, text, line, col });
        }
        _ => {
            toks.push(Tok { kind: TokKind::Punct('\''), text: "'".into(), line, col });
        }
    }
}

fn lex_number(c: &mut Cursor, line: usize, col: usize) -> Tok {
    let start = c.pos;
    let mut float = false;
    if c.peek(0) == Some(b'0') && matches!(c.peek(1), Some(b'x') | Some(b'o') | Some(b'b')) {
        c.bump();
        c.bump();
        while c.peek(0).is_some_and(|b| b.is_ascii_hexdigit() || b == b'_') {
            c.bump();
        }
    } else {
        while c.peek(0).is_some_and(|b| b.is_ascii_digit() || b == b'_') {
            c.bump();
        }
        // Fractional part: `1.25`, or trailing-dot `1.` when the dot is
        // not a range (`0..n`) or a method/field access (`1.max`).
        if c.peek(0) == Some(b'.') {
            match c.peek(1) {
                Some(d) if d.is_ascii_digit() => {
                    float = true;
                    c.bump();
                    while c.peek(0).is_some_and(|b| b.is_ascii_digit() || b == b'_') {
                        c.bump();
                    }
                }
                Some(b'.') => {}
                Some(d) if is_ident_start(d) => {}
                _ => {
                    float = true;
                    c.bump();
                }
            }
        }
        // Exponent.
        if matches!(c.peek(0), Some(b'e') | Some(b'E')) {
            let (a, b2) = (c.peek(1), c.peek(2));
            let exp = match a {
                Some(d) if d.is_ascii_digit() => true,
                Some(b'+') | Some(b'-') => b2.is_some_and(|d| d.is_ascii_digit()),
                _ => false,
            };
            if exp {
                float = true;
                c.bump(); // e
                if matches!(c.peek(0), Some(b'+') | Some(b'-')) {
                    c.bump();
                }
                while c.peek(0).is_some_and(|b| b.is_ascii_digit() || b == b'_') {
                    c.bump();
                }
            }
        }
    }
    // Type suffix (`f64`, `u32`, `usize`, …).
    let suffix_start = c.pos;
    while c.peek(0).is_some_and(is_ident_continue) {
        c.bump();
    }
    let suffix = std::str::from_utf8(&c.src[suffix_start..c.pos]).unwrap_or("").to_string();
    if suffix == "f32" || suffix == "f64" {
        float = true;
    }
    let text = std::str::from_utf8(&c.src[start..c.pos]).unwrap_or("").to_string();
    Tok { kind: TokKind::Number { float, suffix }, text, line, col }
}
