//! Structural pass over the token stream: spans the rules scope by.
//!
//! Nothing here builds a real AST. The rules only need to answer span
//! questions — "is this token inside a `#[cfg(test)]` item?", "is this
//! loop body inside a `ctx.compute_costed(..)` argument list?", "which
//! `fn` encloses this index?" — so this pass records brace-matched token
//! ranges for: test/loom items, `fn` bodies, `.compute*(…)` argument
//! lists, `for`/`while`/`loop` bodies, and name-call sites (the input to
//! the call-graph approximation in [`crate::lint::rules`]).

use crate::lint::lexer::{Tok, TokKind};

/// Inclusive token-index range.
pub type Span = (usize, usize);

/// A `fn` item: its name, the index of the `fn` keyword, and the body
/// brace span (functions without bodies — trait method declarations —
/// are not recorded).
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    pub kw: usize,
    pub body: Span,
}

/// A `for`/`while`/`loop` with its body brace span.
#[derive(Clone, Debug)]
pub struct LoopSpan {
    pub kw: usize,
    pub body: Span,
}

/// A call site `name(` / `.name(` — the raw material of the call graph.
#[derive(Clone, Debug)]
pub struct CallSite {
    pub name: String,
    pub idx: usize,
}

/// Everything the rules need to know about one file's structure.
#[derive(Debug, Default)]
pub struct FileInfo {
    /// Items under `#[test]`, `#[cfg(test)]`, or `#[cfg(loom)]` (and any
    /// `cfg` whose arguments mention `test`/`loom` without `not`):
    /// exempt from every rule.
    pub test_spans: Vec<Span>,
    pub fns: Vec<FnSpan>,
    /// Argument-list spans of `.compute*(…)` calls — work inside these is
    /// priced by the modeled clock.
    pub compute_spans: Vec<Span>,
    pub loops: Vec<LoopSpan>,
    pub calls: Vec<CallSite>,
}

impl FileInfo {
    pub fn in_test(&self, idx: usize) -> bool {
        span_contains(&self.test_spans, idx)
    }

    pub fn in_compute(&self, idx: usize) -> bool {
        span_contains(&self.compute_spans, idx)
    }

    /// Innermost enclosing `fn` body, if any.
    pub fn enclosing_fn(&self, idx: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.body.0 <= idx && idx <= f.body.1)
            .min_by_key(|f| f.body.1 - f.body.0)
    }
}

fn span_contains(spans: &[Span], idx: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// Index of the matching closing delimiter for the opener at `open`
/// (same delimiter class only — the streams are well-nested in any file
/// `rustc` accepts). Returns the last index if unbalanced.
fn match_delim(toks: &[Tok], open: usize, open_ch: char, close_ch: char) -> usize {
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct(open_ch) {
            depth += 1;
        } else if t.is_punct(close_ch) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
    }
    toks.len().saturating_sub(1)
}

const KEYWORDS: &[&str] = &[
    "as", "break", "const", "continue", "crate", "dyn", "else", "enum", "extern", "false", "fn",
    "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "self", "Self", "static", "struct", "super", "trait", "true", "type", "unsafe",
    "use", "where", "while", "async", "await",
];

/// One linear walk collecting every span kind.
pub fn parse(toks: &[Tok]) -> FileInfo {
    let mut info = FileInfo::default();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        match &t.kind {
            TokKind::Punct('#') if toks.get(i + 1).is_some_and(|t| t.is_punct('[')) => {
                let close = match_delim(toks, i + 1, '[', ']');
                if attr_is_test(&toks[i + 1..=close]) {
                    let end = item_end(toks, close + 1);
                    info.test_spans.push((i, end));
                }
                i += 1; // walk *into* the attribute (other rules see it)
            }
            TokKind::Ident => {
                match t.text.as_str() {
                    "fn" => {
                        if let Some(name) = toks.get(i + 1).filter(|t| t.kind == TokKind::Ident) {
                            if let Some(open) = body_open(toks, i + 2) {
                                let close = match_delim(toks, open, '{', '}');
                                info.fns.push(FnSpan {
                                    name: name.text.clone(),
                                    kw: i,
                                    body: (open, close),
                                });
                            }
                        }
                    }
                    "loop" => {
                        if toks.get(i + 1).is_some_and(|t| t.is_punct('{')) {
                            let close = match_delim(toks, i + 1, '{', '}');
                            info.loops.push(LoopSpan { kw: i, body: (i + 1, close) });
                        }
                    }
                    "for" | "while" => {
                        if let Some(open) = loop_body_open(toks, i, t.text == "for") {
                            let close = match_delim(toks, open, '{', '}');
                            info.loops.push(LoopSpan { kw: i, body: (open, close) });
                        }
                    }
                    name => {
                        // `.compute*(…)` argument spans.
                        let dotted = i > 0 && toks[i - 1].is_punct('.');
                        let called = toks.get(i + 1).is_some_and(|t| t.is_punct('('));
                        if dotted && called && name.starts_with("compute") {
                            let close = match_delim(toks, i + 1, '(', ')');
                            info.compute_spans.push((i + 1, close));
                        }
                        // Call sites for the call graph: `name(` that is
                        // not a definition, macro, or keyword.
                        let defined = i > 0 && toks[i - 1].is_ident("fn");
                        let macro_bang = toks.get(i + 1).is_some_and(|t| t.is_punct('!'));
                        if called && !defined && !macro_bang && !KEYWORDS.contains(&name) {
                            info.calls.push(CallSite { name: name.to_string(), idx: i });
                        }
                    }
                }
                i += 1;
            }
            _ => {
                i += 1;
            }
        }
    }
    info
}

/// Does this bracketed attribute mark a test/loom-only item? True for
/// `#[test]`, `#[cfg(test)]`, `#[cfg(loom)]`, `#[cfg(all(test, …))]` — any
/// `cfg`/`test` mention *without* a `not(…)` (so `#[cfg(not(test))]` code
/// is still linted).
fn attr_is_test(attr: &[Tok]) -> bool {
    let mut test = false;
    let mut negated = false;
    for t in attr {
        if t.is_ident("test") || t.is_ident("loom") {
            test = true;
        }
        if t.is_ident("not") {
            negated = true;
        }
    }
    test && !negated
}

/// End of the item following an attribute: the matching `}` of its first
/// top-level brace, or the first `;` if one comes sooner (use/mod decls,
/// trait methods). Skips stacked attributes.
fn item_end(toks: &[Tok], mut i: usize) -> usize {
    while i < toks.len() {
        if toks[i].is_punct('#') && toks.get(i + 1).is_some_and(|t| t.is_punct('[')) {
            i = match_delim(toks, i + 1, '[', ']') + 1;
            continue;
        }
        break;
    }
    let mut depth_paren = 0i64;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct('(') || t.is_punct('[') {
            depth_paren += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            depth_paren -= 1;
        } else if depth_paren == 0 && t.is_punct(';') {
            return i;
        } else if depth_paren == 0 && t.is_punct('{') {
            return match_delim(toks, i, '{', '}');
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Body `{` of a `fn`: first top-level `{` after the signature, unless a
/// `;` ends a bodiless declaration first.
fn body_open(toks: &[Tok], mut i: usize) -> Option<usize> {
    let mut depth = 0i64;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            depth -= 1;
        } else if depth == 0 && t.is_punct(';') {
            return None;
        } else if depth == 0 && t.is_punct('{') {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// Body `{` of a `for`/`while` loop header. For `for`, an `in` at
/// delimiter depth 0 must appear first — `impl Trait for Type { … }` and
/// HRTB `for<'a>` have none and are rejected.
fn loop_body_open(toks: &[Tok], kw: usize, is_for: bool) -> Option<usize> {
    let mut depth = 0i64;
    let mut saw_in = false;
    let mut i = kw + 1;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            depth -= 1;
        } else if depth == 0 && t.is_ident("in") {
            saw_in = true;
        } else if depth == 0 && t.is_punct('{') {
            if is_for && !saw_in {
                return None;
            }
            return Some(i);
        } else if depth == 0 && t.is_punct(';') {
            return None;
        }
        i += 1;
    }
    None
}
