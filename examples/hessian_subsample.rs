//! Figure 5: how many samples are needed to compute the Hessian?
//! DiSCO-F with the HVP restricted to a uniformly resampled fraction of
//! the data per outer iteration (the paper's §5.4 experiment, no theory).
//!
//! ```bash
//! cargo run --release --example hessian_subsample -- --dataset rcv1s --scale 4
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::registry;
use disco::loss::LossKind;
use disco::util::cli::Args;

fn main() {
    let args = Args::new(
        "hessian_subsample",
        "paper Figure 5: Hessian subsampling sweep for DiSCO-F",
    )
    .opt("dataset", Some("rcv1s"), "dataset name")
    .opt("scale", Some("4"), "dataset down-scale factor")
    .opt("grad-tol", Some("1e-7"), "target accuracy")
    .parse_env()
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let name = args.get("dataset").unwrap();
    let ds = registry::load_scaled(&name, args.get_usize("scale").unwrap()).expect("dataset");
    let lambda = registry::spec(&name).unwrap().lambda;
    println!("{}\n", ds.describe());

    println!(
        "{:>9} {:>8} {:>12} {:>12} {:>10}",
        "fraction", "rounds", "sim_time", "‖∇f‖", "converged"
    );
    for frac in [1.0, 0.5, 0.25, 0.125, 0.0625] {
        let mut cfg = RunConfig::new(AlgoKind::DiscoF, LossKind::Logistic, lambda);
        cfg.hessian_fraction = frac;
        cfg.grad_tol = args.get_f64("grad-tol").unwrap();
        cfg.max_outer = 80;
        let res = run(&ds, &cfg);
        println!(
            "{:>8.2}% {:>8} {:>11.4}s {:>12.3e} {:>10}",
            100.0 * frac,
            res.stats.rounds(),
            res.sim_seconds,
            res.final_grad_norm(),
            res.converged
        );
    }
    println!(
        "\nexpected shape (paper Fig. 5): for n ≫ d data (rcv1 regime) small\nfractions still converge and can win in time; for d ≫ n (news20) the\nsubsampled Hessian misses feature interactions and hurts."
    );
}
