//! Ablation: where does the DiSCO-F vs DiSCO-S crossover fall as the
//! network changes?
//!
//! The paper's §5.2 explains the rcv1 result (S wins time despite F
//! winning rounds) by message sizes: F moves ℝⁿ per PCG step, S moves
//! 2×ℝᵈ. This sweep varies bandwidth β (at fixed 50 µs latency) on both
//! an n≫d and a d≫n dataset and reports simulated time-to-target,
//! locating the crossover the paper only gestures at.
//!
//! ```bash
//! cargo run --release --example network_sweep
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::registry;
use disco::loss::LossKind;
use disco::net::CostModel;

fn main() {
    let tol = 1e-6;
    for name in ["rcv1s", "news20s"] {
        let ds = registry::load_scaled(name, 4).expect("dataset");
        let lambda = registry::spec(name).unwrap().lambda;
        println!(
            "=== {name} (n={}, d={}) — simulated seconds to ‖∇f‖ ≤ {tol:.0e} ===",
            ds.nsamples(),
            ds.dim()
        );
        println!(
            "{:>14} {:>12} {:>12} {:>10}",
            "bandwidth", "DiSCO-F", "DiSCO-S", "winner"
        );
        for beta in [12.5e6, 125e6, 1.25e9, 12.5e9, f64::INFINITY] {
            let cost = CostModel { alpha: 50e-6, beta, ..CostModel::default() };
            let mut times = Vec::new();
            for algo in [AlgoKind::DiscoF, AlgoKind::DiscoS] {
                let mut cfg = RunConfig::new(algo, LossKind::Logistic, lambda);
                cfg.cost = cost;
                cfg.grad_tol = tol;
                cfg.max_outer = 40;
                let res = run(&ds, &cfg);
                times.push(res.time_to_tol(tol));
            }
            let label = if beta.is_infinite() {
                "∞ (free)".to_string()
            } else {
                format!("{:.3} GB/s", beta / 1e9)
            };
            let fmt = |t: Option<f64>| t.map(|v| format!("{v:.4}s")).unwrap_or("—".into());
            let winner = match (times[0], times[1]) {
                (Some(f), Some(s)) if f < s => "F",
                (Some(_), Some(_)) => "S",
                _ => "?",
            };
            println!(
                "{label:>14} {:>12} {:>12} {:>10}",
                fmt(times[0]),
                fmt(times[1]),
                winner
            );
        }
        println!();
    }
    println!("expected shape: slow networks amplify message-size differences —\nd≫n favors F at every bandwidth; n≫d flips to S once bandwidth (not\nlatency) dominates, matching the paper's rcv1 vs news20 discussion.");
}
