//! Ablation: nnz-balanced vs count-balanced feature partitioning.
//!
//! The paper's DiSCO-F claim is that all nodes do "exactly the same work";
//! with contiguous equal-*count* feature shards on Zipf-distributed text
//! data that is false — node 0 gets the head features and most of the
//! nonzeros. `Partition::by_features_balanced` cuts at nnz quantiles
//! instead. This example measures shard imbalance, per-node compute
//! balance, and end-to-end simulated time for both strategies.
//!
//! ```bash
//! cargo run --release --example partition_balance
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::{registry, Partition, SyntheticConfig};
use disco::loss::LossKind;

fn main() {
    // Strongly Zipf-skewed corpus (exponent 1.3).
    let ds = SyntheticConfig::new("zipfy", 4096, 8192)
        .density(0.004)
        .zipf(1.3)
        .seed(31)
        .generate();
    println!("{}\n", ds.describe());

    let tau = 100.0;
    let show = |name: &str, p: &Partition| {
        println!(
            "{name:<22} nnz={:?} d_j={:?}  nnz-imbalance {:.2}",
            p.shards.iter().map(|s| s.x.nnz()).collect::<Vec<_>>(),
            p.shards.iter().map(|s| s.len()).collect::<Vec<_>>(),
            p.imbalance()
        );
    };
    show("count-balanced:", &Partition::by_features(&ds, 4));
    show("nnz-balanced (κ=0):", &Partition::by_features_balanced(&ds, 4));
    show(
        "cost-balanced (κ=2τ):",
        &Partition::by_features_cost_balanced(&ds, 4, 2.0 * tau + 10.0),
    );
    println!();

    let lambda = registry::spec("news20s").unwrap().lambda;
    for (name, flag) in [("count-balanced", false), ("nnz-balanced", true)] {
        let mut cfg = RunConfig::new(AlgoKind::DiscoF, LossKind::Logistic, lambda);
        cfg.balanced_partition = flag;
        cfg.grad_tol = 1e-8;
        cfg.max_outer = 40;
        cfg.trace = true;
        let res = run(&ds, &cfg);
        println!(
            "{name:<16} rounds={:>5} sim_time={:.3}s compute_balance={:.2} utilization={:.1}% converged={}",
            res.stats.rounds(),
            res.sim_seconds,
            res.trace.compute_balance(),
            100.0 * res.trace.utilization(),
            res.converged
        );
    }
    println!(
        "\nfinding (recorded in EXPERIMENTS.md): pure-nnz balancing over-packs tail\nfeatures onto one node — its O(d_j·τ) Woodbury/vector work then dominates on\nsparse data. The κ=2τ cost model balances both terms."
    );
}
