//! Quickstart: train ℓ2-regularized logistic regression with DiSCO-F on a
//! 4-node simulated cluster, print the convergence table, and sanity-check
//! the result against the single-machine Newton reference.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::SyntheticConfig;
use disco::linalg::ops;
use disco::loss::{LossKind, Objective};
use disco::solvers::newton_reference;

fn main() {
    // A small text-classification-shaped problem: 2 000 sparse samples,
    // 1 000 features, ±1 labels from a noisy planted model.
    let ds = SyntheticConfig::new("quickstart", 2000, 1000)
        .density(0.02)
        .label_noise(0.1)
        .seed(7)
        .generate();
    println!("{}", ds.describe());

    let mut cfg = RunConfig::new(AlgoKind::DiscoF, LossKind::Logistic, 1e-3);
    cfg.m = 4;
    cfg.tau = 100; // paper §5.2 default
    cfg.grad_tol = 1e-9;
    cfg.max_outer = 50;

    println!(
        "\nDiSCO-F, m={} nodes, τ={}, λ={:.0e}\n{:>5} {:>8} {:>10} {:>12} {:>14}",
        cfg.m, cfg.tau, cfg.lambda, "outer", "rounds", "sim_time", "‖∇f(w_k)‖", "f(w_k)"
    );
    let res = run(&ds, &cfg);
    for r in &res.records {
        println!(
            "{:>5} {:>8} {:>9.4}s {:>12.3e} {:>14.8}",
            r.outer, r.rounds, r.sim_time, r.grad_norm, r.fval
        );
    }
    println!(
        "\nconverged={} in {} communication rounds ({} KB moved, {:.1} ms modeled network time)",
        res.converged,
        res.stats.rounds(),
        res.stats.vector_bytes() / 1024,
        res.stats.modeled_comm_seconds * 1e3
    );

    // Cross-check against the single-machine Newton reference.
    let loss = cfg.loss.make();
    let obj = Objective::new(&ds.x, &ds.y, loss.as_ref(), cfg.lambda);
    let reference = newton_reference(&obj, 1e-10, 60, 2000);
    let mut diff = vec![0.0; ds.dim()];
    ops::sub(&res.w, &reference.w, &mut diff);
    println!(
        "distance to single-machine optimum: ‖w − w*‖ = {:.3e} (f − f* = {:.3e})",
        ops::norm2(&diff),
        obj.value(&res.w) - reference.fval
    );
    assert!(res.converged, "quickstart failed to converge");
}
