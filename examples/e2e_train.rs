//! End-to-end validation (DESIGN.md §5): the full three-layer stack on a
//! realistic workload.
//!
//! Loads the AOT artifacts (Pallas kernels → jax graph → HLO text),
//! compiles them on the PJRT CPU client, and trains ℓ2-regularized
//! logistic regression with **XLA-backed DiSCO-F** on a 4-node simulated
//! cluster over a dense d=1024 × n=4096 planted-model corpus, logging the
//! loss / gradient-norm curve to `results/e2e_train.csv`. A native f64 run
//! of the identical configuration is recorded alongside for comparison,
//! proving the layers compose (same rounds, same trajectory to f32
//! precision).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::SyntheticConfig;
use disco::linalg::ops;
use disco::loss::LossKind;
use disco::net::CostModel;
use disco::runtime::{artifact_dir, run_disco_f_xla, Engine};
use disco::util::csv::{sci, secs, CsvWriter};

fn main() {
    let dir = artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts at {dir:?}; run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Engine::cpu(dir).expect("PJRT engine");
    println!("PJRT platform: {}", engine.platform());

    // d=1024, n=4096 — a registered artifact shape; m=4 ⇒ 256×4096 shards.
    let ds = SyntheticConfig::new("e2e", 4096, 1024)
        .label_noise(0.1)
        .seed(20260710)
        .generate_dense();
    println!("{}", ds.describe());

    let mut cfg = RunConfig::new(AlgoKind::DiscoF, LossKind::Logistic, 1e-4);
    cfg.m = 4;
    cfg.tau = 128;
    cfg.grad_tol = 1e-6; // f32 artifact precision floor
    cfg.max_outer = 40;
    cfg.cost = CostModel::default();

    println!("\n=== XLA-backed DiSCO-F (full request path through PJRT) ===");
    let t = std::time::Instant::now();
    let xla = run_disco_f_xla(&ds, &cfg, &engine).expect("xla run");
    println!(
        "{:>5} {:>8} {:>10} {:>12} {:>14} {:>6}",
        "outer", "rounds", "sim_time", "‖∇f‖", "f", "pcg"
    );
    for r in &xla.records {
        println!(
            "{:>5} {:>8} {:>9.4}s {:>12.3e} {:>14.8} {:>6}",
            r.outer, r.rounds, r.sim_time, r.grad_norm, r.fval, r.inner_iters
        );
    }
    println!(
        "converged={} | rounds={} | artifact executions={} | wall {:.2}s",
        xla.converged,
        xla.stats.rounds(),
        engine.total_executions(),
        t.elapsed().as_secs_f64()
    );

    println!("\n=== native f64 DiSCO-F (same configuration) ===");
    let native = run(&ds, &cfg);
    println!(
        "converged={} | rounds={} | final ‖∇f‖={:.3e} | f={:.8}",
        native.converged,
        native.stats.rounds(),
        native.final_grad_norm(),
        native.final_fval()
    );

    let mut diff = vec![0.0; ds.dim()];
    ops::sub(&xla.w, &native.w, &mut diff);
    println!(
        "‖w_xla − w_native‖ = {:.3e} (relative {:.3e})",
        ops::norm2(&diff),
        ops::norm2(&diff) / (1.0 + ops::norm2(&native.w))
    );

    // Tidy CSV for EXPERIMENTS.md.
    let mut w = CsvWriter::create(
        "results/e2e_train.csv",
        &["path", "outer", "rounds", "sim_time_s", "grad_norm", "fval", "pcg_iters"],
    )
    .expect("csv");
    for (path, res) in [("xla", &xla), ("native", &native)] {
        for r in &res.records {
            w.row(&[
                path.into(),
                r.outer.to_string(),
                r.rounds.to_string(),
                secs(r.sim_time),
                sci(r.grad_norm),
                sci(r.fval),
                r.inner_iters.to_string(),
            ])
            .unwrap();
        }
    }
    println!("\nwrote results/e2e_train.csv ({} rows)", w.rows_written());
    assert!(xla.converged && native.converged, "e2e failed to converge");
    assert_eq!(
        xla.stats.vector_rounds, native.stats.vector_rounds,
        "XLA and native paths must count identical communication"
    );
}
