//! Figure 4: impact of the preconditioner sample count τ on DiSCO-F.
//! Larger τ ⇒ better preconditioner ⇒ fewer communication rounds, but more
//! per-step Woodbury work — the paper finds τ=100 the sweet spot in time.
//!
//! ```bash
//! cargo run --release --example tau_sweep -- --dataset rcv1s --scale 4
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::registry;
use disco::loss::LossKind;
use disco::util::cli::Args;

fn main() {
    let args = Args::new("tau_sweep", "paper Figure 4: τ sweep for DiSCO-F")
        .opt("dataset", Some("rcv1s"), "dataset name")
        .opt("scale", Some("4"), "dataset down-scale factor")
        .opt("grad-tol", Some("1e-8"), "target accuracy")
        .parse_env()
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let name = args.get("dataset").unwrap();
    let scale = args.get_usize("scale").unwrap();
    let ds = registry::load_scaled(&name, scale).expect("unknown dataset");
    let lambda = registry::spec(&name).unwrap().lambda;
    println!("{}\n", ds.describe());

    println!(
        "{:>5} {:>8} {:>12} {:>12} {:>14} {:>10}",
        "τ", "rounds", "sim_time", "‖∇f‖", "outer iters", "converged"
    );
    for tau in [25usize, 50, 100, 200, 400] {
        let mut cfg = RunConfig::new(AlgoKind::DiscoF, LossKind::Logistic, lambda);
        cfg.tau = tau;
        cfg.grad_tol = args.get_f64("grad-tol").unwrap();
        cfg.max_outer = 60;
        let res = run(&ds, &cfg);
        println!(
            "{:>5} {:>8} {:>11.4}s {:>12.3e} {:>14} {:>10}",
            tau,
            res.stats.rounds(),
            res.sim_seconds,
            res.final_grad_norm(),
            res.records.len(),
            res.converged
        );
    }
    println!("\nexpected shape (paper Fig. 4): rounds decrease with τ; elapsed time is\nbest at a moderate τ (≈100) because Woodbury work grows with τ.");
}
