//! Figure-3-style comparison: DiSCO-F vs DiSCO-S vs original DiSCO vs
//! DANE vs CoCoA+ on one dataset/loss, reporting ‖∇f‖ against both
//! communication rounds and simulated elapsed time.
//!
//! ```bash
//! cargo run --release --example compare_algorithms -- --dataset news20s --scale 4
//! ```

use disco::coordinator::experiments::{figure3_one, ExperimentConfig};
use disco::loss::LossKind;
use disco::util::cli::Args;

fn main() {
    let args = Args::new("compare_algorithms", "paper Figure 3 for one dataset/loss")
        .opt("dataset", Some("news20s"), "news20s | rcv1s | splices | tiny")
        .opt("loss", Some("logistic"), "logistic | quadratic")
        .opt("scale", Some("4"), "dataset down-scale factor")
        .opt("max-outer", Some("40"), "outer iteration cap")
        .parse_env()
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });

    let mut cfg = ExperimentConfig::default();
    cfg.scale = args.get_usize("scale").unwrap();
    cfg.max_outer = args.get_usize("max-outer").unwrap();
    cfg.grad_target = 1e-8;
    let dataset = args.get("dataset").unwrap();
    let loss = LossKind::parse(&args.get("loss").unwrap()).expect("bad --loss");

    let (summary, results) = figure3_one(&cfg, &dataset, loss).expect("figure3 run");
    println!("{summary}");

    // Paper-style readout: rounds and time to reach three accuracy levels.
    for tol in [1e-2, 1e-4, 1e-6] {
        println!("--- to reach ‖∇f‖ ≤ {tol:.0e} ---");
        for (algo, res) in &results {
            match (res.rounds_to_tol(tol), res.time_to_tol(tol)) {
                (Some(r), Some(t)) => {
                    println!("{:<8} {:>7} rounds   {:>9.3}s", algo.name(), r, t)
                }
                _ => println!("{:<8}     (not reached)", algo.name()),
            }
        }
    }
    println!("\nCSV written to results/fig3_{dataset}_{}.csv", loss.name());
}
