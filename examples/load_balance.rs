//! Figure 2: per-node activity flow (compute / communicate / idle) for
//! DiSCO-S vs DiSCO-F vs original DiSCO — the load-balancing story.
//!
//! DiSCO-S serializes all PCG vector operations (and the preconditioner
//! solve) on the master; its workers idle between Hessian products.
//! Original DiSCO makes this far worse (SAG inner solve on the master).
//! DiSCO-F gives every node identical work. The ASCII Gantt charts below
//! are the measured equivalents of the paper's Figure 2 box diagrams.
//!
//! ```bash
//! cargo run --release --example load_balance
//! ```

use disco::algorithms::{run, AlgoKind, RunConfig};
use disco::data::registry;
use disco::loss::LossKind;
use disco::net::CostModel;

fn main() {
    let ds = registry::load("tiny").expect("dataset");
    let lambda = registry::spec("tiny").unwrap().lambda;
    println!("{}\n", ds.describe());

    for algo in [AlgoKind::DiscoS, AlgoKind::DiscoOrig, AlgoKind::DiscoF] {
        let mut cfg = RunConfig::new(algo, LossKind::Logistic, lambda);
        cfg.m = 4;
        cfg.tau = 64;
        cfg.trace = true;
        cfg.max_outer = 2; // a few iterations, like the paper's diagram
        cfg.grad_tol = 0.0;
        cfg.cost = CostModel::default();
        let res = run(&ds, &cfg);
        println!("=== {} ===", algo.name());
        println!("{}", res.trace.render_ascii(100));
        println!(
            "cluster utilization: {:.1}%   compute balance (min/max node): {:.2}\n",
            100.0 * res.trace.utilization(),
            res.trace.compute_balance()
        );
    }
    println!(
        "expected shape (paper Fig. 2): DiSCO-F ≫ DiSCO-S ≫ original DiSCO in\nutilization; the master row of DiSCO-S/DiSCO stays busy while workers idle."
    );
}
