"""Layer-2 correctness: model.py compute graphs vs numpy math, including
the scaling conventions the Rust coordinator depends on."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _problem(seed, d=32, n=48):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(d, n)).astype("float32"))
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n).astype("float32"))
    w = jnp.asarray((0.3 * rng.normal(size=d)).astype("float32"))
    return x, y, w


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), loss=st.sampled_from(model.LOSSES))
def test_grad_matches_numpy_fd(seed, loss):
    x, y, w = _problem(seed)
    lam = 0.05
    n = x.shape[1]
    (z,) = model.margins(x, w)
    grad_fn = model.make_grad_fn(loss)
    (g,) = grad_fn(
        x, z, y,
        jnp.asarray([1.0 / n], dtype="float32"),
        jnp.asarray([lam], dtype="float32"),
        w,
    )
    # Finite differences on f(w) = (1/n) sum phi + lam/2 |w|^2 (float64).
    xf = np.asarray(x, dtype="float64")
    yf = np.asarray(y, dtype="float64")
    wf = np.asarray(w, dtype="float64")

    def f(wv):
        zv = xf.T @ wv
        if loss == "logistic":
            v = np.logaddexp(0.0, -yf * zv)
        else:
            v = (zv - yf) ** 2
        return v.mean() + 0.5 * lam * (wv @ wv)

    h = 1e-6
    for k in range(0, x.shape[0], 7):
        wp, wm = wf.copy(), wf.copy()
        wp[k] += h
        wm[k] -= h
        fd = (f(wp) - f(wm)) / (2 * h)
        assert abs(fd - float(g[k])) < 5e-3 * (1 + abs(fd)), (loss, k)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), loss=st.sampled_from(model.LOSSES))
def test_hvp_matches_ref_with_loss_scalings(seed, loss):
    x, y, w = _problem(seed)
    rng = np.random.default_rng(seed + 1)
    u = jnp.asarray(rng.normal(size=x.shape[0]).astype("float32"))
    (z,) = model.margins(x, w)
    (s,) = model.make_scalings_fn(loss)(z, y)
    n = x.shape[1]
    lam = 0.02
    (hu,) = model.local_hvp(
        x, s, u,
        jnp.asarray([1.0 / n], dtype="float32"),
        jnp.asarray([lam], dtype="float32"),
    )
    want = ref.hvp(x, s, u, 1.0 / n, lam)
    np.testing.assert_allclose(hu, want, rtol=3e-4, atol=3e-4)
    # SPD check: u^T H u >= lam |u|^2.
    quad = float(u @ hu)
    assert quad >= lam * float(u @ u) - 1e-3


@pytest.mark.parametrize("loss", model.LOSSES)
def test_objective_value_matches_numpy(loss):
    x, y, w = _problem(3)
    (z,) = model.margins(x, w)
    n = x.shape[1]
    (val,) = model.make_objective_fn(loss)(z, y, jnp.asarray([1.0 / n], dtype="float32"))
    zf = np.asarray(z, dtype="float64")
    yf = np.asarray(y, dtype="float64")
    if loss == "logistic":
        want = np.logaddexp(0.0, -yf * zf).mean()
    else:
        want = ((zf - yf) ** 2).mean()
    assert abs(float(val[0]) - want) < 1e-4 * (1 + abs(want))


def test_feature_shards_compose_to_full_margins():
    # DiSCO-F identity: margins of row-blocks sum to the full margins.
    x, y, w = _problem(5, d=64, n=32)
    (z_full,) = model.margins(x, w)
    z_sum = jnp.zeros_like(z_full)
    for lo, hi in [(0, 16), (16, 40), (40, 64)]:
        (zj,) = model.margins(x[lo:hi, :], w[lo:hi])
        z_sum = z_sum + zj
    np.testing.assert_allclose(z_sum, z_full, rtol=3e-4, atol=3e-4)


def test_woodbury_gram_matches_ref():
    rng = np.random.default_rng(9)
    us = jnp.asarray(rng.normal(size=(64, 16)).astype("float32"))
    (k,) = model.woodbury_gram(us)
    np.testing.assert_allclose(k, ref.gram(us), rtol=3e-4, atol=3e-4)
