"""AOT pipeline: manifest consistency and HLO-text artifact validity."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_list_is_complete_and_unique():
    names = [name for name, _, _ in aot.artifact_list()]
    assert len(names) == len(set(names))
    # Every shape variant gets margins + hvp + per-loss grads + gram.
    for d, n in aot.SHAPES:
        assert f"margins_{d}x{n}" in names
        assert f"hvp_{d}x{n}" in names
        for loss in ("logistic", "quadratic"):
            assert f"grad_{loss}_{d}x{n}" in names
        assert f"gram_{d}x{aot.TAU}" in names


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_matches_files_and_schema():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest) >= 30
    for name, meta in manifest.items():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path), name
        body = open(path).read()
        assert body.startswith("HloModule"), f"{name} is not HLO text"
        assert len(meta["inputs"]) >= 1
        assert len(meta["outputs"]) >= 1
        for io in meta["inputs"] + meta["outputs"]:
            assert io["dtype"] == "f32"
            assert all(isinstance(s, int) and s > 0 for s in io["shape"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_hvp_artifact_mentions_expected_shapes():
    body = open(os.path.join(ART, "hvp_64x128.hlo.txt")).read()
    assert "f32[64,128]" in body
    assert "f32[128]" in body


def test_lowering_is_reproducible(tmp_path):
    # Lower one artifact twice; HLO text must be byte-identical (the Rust
    # runtime caches compiled executables by name).
    import jax

    name, fn, args = next(iter(aot.artifact_list()))
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert t1 == t2


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_every_artifact_keeps_all_parameters():
    # Regression: jit lowering prunes arguments with no data dependence
    # (e.g. a constant phi'' dropped z and y), which breaks the Rust
    # runtime's fixed call signatures. Every artifact's HLO entry must
    # declare exactly len(inputs) parameters.
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, meta in manifest.items():
        body = open(os.path.join(ART, meta["file"])).read()
        # Count parameters of the ENTRY computation only (nested reduce
        # computations have their own parameter(0)/(1) declarations).
        entry = body[body.index("\nENTRY "):]
        entry = entry[: entry.index("\n}") + 2]
        declared = sum(1 for line in entry.splitlines() if " parameter(" in line)
        assert declared == len(meta["inputs"]), (
            f"{name}: ENTRY has {declared} parameters, manifest expects "
            f"{len(meta['inputs'])} (argument pruned at lowering?)"
        )
