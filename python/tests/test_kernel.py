"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and block sizes) so tiling/accumulation bugs
that only appear at particular grid aspect ratios are caught. This is the
CORE correctness signal for the compute layer -- the Rust runtime executes
exactly these lowered kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, matvec, ref

# Reproducible case generator.
def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype("float32"))


dims = st.sampled_from([1, 2, 3, 4, 8, 16, 31, 64, 100, 128])
blocks = st.sampled_from([8, 16, 32, 64, 512])


@settings(max_examples=60, deadline=None)
@given(d=dims, n=dims, bd=blocks, bn=blocks, seed=st.integers(0, 2**16))
def test_xt_matvec_matches_ref(d, n, bd, bn, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, d, n)
    u = _rand(rng, d)
    got = matvec.xt_matvec(x, u, block_d=bd, block_n=bn)
    np.testing.assert_allclose(got, ref.margins(x, u), rtol=2e-4, atol=2e-4)


@settings(max_examples=60, deadline=None)
@given(d=dims, n=dims, bd=blocks, bn=blocks, seed=st.integers(0, 2**16))
def test_x_scaled_matvec_matches_ref(d, n, bd, bn, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, d, n)
    c = _rand(rng, n)
    got = matvec.x_scaled_matvec(x, c, block_d=bd, block_n=bn)
    np.testing.assert_allclose(got, ref.scaled_matvec(x, c), rtol=2e-4, atol=2e-4)


@settings(max_examples=40, deadline=None)
@given(d=dims, tau=st.sampled_from([1, 2, 5, 16, 33]), bd=blocks,
       seed=st.integers(0, 2**16))
def test_gram_matches_ref(d, tau, bd, seed):
    rng = np.random.default_rng(seed)
    us = _rand(rng, d, tau)
    got = gram.gram(us, block_d=bd)
    np.testing.assert_allclose(got, ref.gram(us), rtol=3e-4, atol=3e-4)
    # Gram matrices are symmetric PSD.
    got = np.asarray(got)
    np.testing.assert_allclose(got, got.T, rtol=1e-6, atol=1e-6)
    eig = np.linalg.eigvalsh(got)
    assert eig.min() >= -1e-3


def test_block_divisor_helper():
    assert matvec._divisor_block(128, 512) == 128
    assert matvec._divisor_block(1024, 256) == 256
    assert matvec._divisor_block(100, 64) == 50
    assert matvec._divisor_block(7, 4) == 1


def test_vmem_budget_for_registry_shapes():
    # Structure target from DESIGN.md par. 8: each grid step's working set
    # fits a 2 MiB VMEM budget for every artifact shape.
    for d, n in [(64, 128), (256, 4096), (1024, 1024), (1024, 4096)]:
        assert matvec.vmem_bytes(d, n) <= 2 * 1024 * 1024, (d, n)


@pytest.mark.parametrize("d,n", [(64, 128), (256, 512)])
def test_kernels_are_deterministic(d, n):
    rng = np.random.default_rng(7)
    x = _rand(rng, d, n)
    u = _rand(rng, d)
    a = np.asarray(matvec.xt_matvec(x, u))
    b = np.asarray(matvec.xt_matvec(x, u))
    np.testing.assert_array_equal(a, b)
