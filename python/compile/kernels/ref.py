"""Pure-jnp reference oracle for the Pallas kernels (Layer 1 correctness).

Every kernel in this package has an exact counterpart here, written with
plain jax.numpy so there is no shared code with the kernels. pytest (with
hypothesis sweeps over shapes) asserts `assert_allclose(kernel, ref)`.

Conventions (matching the Rust side, see rust/src/linalg/dense.rs):
  X : (d, n) float32, columns are samples.
  All products keep the paper's scaling: the 1/n (or 1/h for subsampled
  Hessians) and the +lambda*u regularizer term are explicit arguments.
"""

import jax.numpy as jnp


def margins(x, w):
    """z = X^T w in R^n."""
    return x.T @ w


def scaled_matvec(x, coeff):
    """y = X @ coeff in R^d (gradient/HVP down-sweep)."""
    return x @ coeff


def hvp(x, s, u, inv_div, lam):
    """Regularized Hessian-vector product:

        Hu = inv_div * X diag(s) X^T u + lam * u
    """
    t = x.T @ u
    return inv_div * (x @ (s * t)) + lam * u


def grad_data(x, dvec, inv_n):
    """Data term of the gradient: g = inv_n * X @ dvec (dvec = phi'(z;y))."""
    return inv_n * (x @ dvec)


def gram(u_scaled):
    """K = U~^T U~ in R^{tau x tau} -- the Woodbury inner Gram matrix
    (before the +I and 1/dreg scaling, which the Rust coordinator owns)."""
    return u_scaled.T @ u_scaled


def logistic_deriv(z, y):
    """d/dz log(1+exp(-y z)) = -y * sigmoid(-y z)."""
    return -y / (1.0 + jnp.exp(y * z))


def logistic_second(z, y):
    s = 1.0 / (1.0 + jnp.exp(-y * z))
    return s * (1.0 - s)


def logistic_value(z, y):
    return jnp.logaddexp(0.0, -y * z)


def quadratic_deriv(z, y):
    return 2.0 * (z - y)


def quadratic_second(z, y):
    # The 0*z + 0*y terms keep a data dependence on both arguments so that
    # jax.jit's AOT lowering does not prune them from the artifact's
    # parameter list (the Rust runtime calls every scalings_* artifact with
    # the same (z, y) signature).
    return jnp.full_like(z, 2.0) + 0.0 * z + 0.0 * y


def quadratic_value(z, y):
    return (z - y) ** 2
