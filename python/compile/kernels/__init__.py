"""Pallas kernels (Layer 1) and their pure-jnp oracle (ref.py)."""

from . import gram, matvec, ref  # noqa: F401
