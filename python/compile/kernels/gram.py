"""Layer-1 Pallas kernel: Woodbury inner Gram matrix.

K = U~^T U~ for the scaled preconditioner columns U~ (d x tau). The grid
walks feature blocks of U~, accumulating the (tau x tau) Gram in the output
block; the tau x tau Cholesky + triangular solves stay in the Rust
coordinator (they are O(tau^2..3) on tau ~ 100 -- negligible, and keeping
them in L3 avoids LAPACK custom-calls in the artifact).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matvec import _divisor_block, BLOCK_D


def _gram_kernel(u_ref, k_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        k_ref[...] = jnp.zeros_like(k_ref)

    k_ref[...] += u_ref[...].T @ u_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d",))
def gram(u_scaled, block_d: int = BLOCK_D):
    """K = U~^T U~ via a feature-block Pallas grid."""
    d, tau = u_scaled.shape
    bd = _divisor_block(d, block_d)
    return pl.pallas_call(
        _gram_kernel,
        out_shape=jax.ShapeDtypeStruct((tau, tau), u_scaled.dtype),
        grid=(d // bd,),
        in_specs=[pl.BlockSpec((bd, tau), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tau, tau), lambda i: (0, 0)),
        interpret=True,
    )(u_scaled)
