"""Layer-1 Pallas kernels: the PCG hot-spot matvecs.

The paper's per-PCG-step compute is two skinny products against the shard's
data block (Algorithm 2/3 step 4):

  up-sweep    t = X^T u     (gather over samples)
  down-sweep  y = X  c      (scatter over features)

Both are expressed as tiled Pallas kernels so the HBM<->VMEM schedule is
explicit (DESIGN.md "Hardware adaptation"): the grid walks (feature-block,
sample-block) tiles of X exactly once, each tile sized to fit VMEM
(<= 2 MiB), with accumulation over the contraction axis in the output
block. The contraction `x_tile.T @ u_tile` / `x_tile @ c_tile` is an
MXU-shaped (128-multiple) matmul on real TPU; `interpret=True` is required
on this image's CPU PJRT (Mosaic custom-calls cannot execute there), so
these kernels are *structurally* TPU-ready and *numerically* validated
against `ref.py`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile targets: 256x512 f32 = 512 KiB per X tile.
BLOCK_D = 256
BLOCK_N = 512


def _divisor_block(dim: int, target: int) -> int:
    """Largest block size <= target that divides dim (shapes in the
    artifact registry are powers of two, so this is exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _xt_kernel(x_ref, u_ref, t_ref):
    # Accumulate t[j_block] += X[i_block, j_block]^T @ u[i_block] over i.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    t_ref[...] += x_ref[...].T @ u_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "block_n"))
def xt_matvec(x, u, block_d: int = BLOCK_D, block_n: int = BLOCK_N):
    """t = X^T u via a (sample-block, feature-block) Pallas grid."""
    d, n = x.shape
    bd = _divisor_block(d, block_d)
    bn = _divisor_block(n, block_n)
    grid = (n // bn, d // bd)
    return pl.pallas_call(
        _xt_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bd,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        interpret=True,
    )(x, u)


def _xc_kernel(x_ref, c_ref, y_ref):
    # Accumulate y[i_block] += X[i_block, j_block] @ c[j_block] over j.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += x_ref[...] @ c_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "block_n"))
def x_scaled_matvec(x, c, block_d: int = BLOCK_D, block_n: int = BLOCK_N):
    """y = X @ c via a (feature-block, sample-block) Pallas grid."""
    d, n = x.shape
    bd = _divisor_block(d, block_d)
    bn = _divisor_block(n, block_n)
    grid = (d // bd, n // bn)
    return pl.pallas_call(
        _xc_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i, j: (i,)),
        interpret=True,
    )(x, c)


def vmem_bytes(d: int, n: int, block_d: int = BLOCK_D, block_n: int = BLOCK_N) -> int:
    """Estimated VMEM footprint of one grid step (X tile + vectors), bytes.
    Used by the structure tests and the DESIGN.md roofline estimate."""
    bd = _divisor_block(d, block_d)
    bn = _divisor_block(n, block_n)
    return 4 * (bd * bn + bd + bn)
