"""Layer-2 JAX compute graph: the per-shard functions the Rust coordinator
executes through PJRT on the request path.

Each function here is a pure jax function calling the Layer-1 Pallas
kernels; `aot.py` lowers them (per shape variant x loss) to HLO text in
artifacts/. Scalars (lambda, 1/n, 1/h) arrive as shape-(1,) f32 inputs so
one artifact serves every dataset configuration of a given shape.

The sample-count normalization convention matches the Rust native path
(rust/src/loss/objective.rs): data terms are divided by the *global* n,
the +lambda*w / +lambda*u regularizer terms are added here per shard slice
(each node owns a disjoint slice of w under DiSCO-F, so the sum over
shards is exact; under DiSCO-S the caller passes lam=0 and adds lambda*w
once after the ReduceAll).
"""

import jax.numpy as jnp

from .kernels import gram as gram_k
from .kernels import matvec, ref

LOSSES = ("logistic", "quadratic")


def _deriv(loss, z, y):
    if loss == "logistic":
        return ref.logistic_deriv(z, y)
    if loss == "quadratic":
        return ref.quadratic_deriv(z, y)
    raise ValueError(loss)


def _second(loss, z, y):
    if loss == "logistic":
        return ref.logistic_second(z, y)
    if loss == "quadratic":
        return ref.quadratic_second(z, y)
    raise ValueError(loss)


def _value(loss, z, y):
    if loss == "logistic":
        return ref.logistic_value(z, y)
    if loss == "quadratic":
        return ref.quadratic_value(z, y)
    raise ValueError(loss)


def margins(x, w):
    """z = X^T w  (the DiSCO-F up-sweep; ReduceAll'd across shards)."""
    return (matvec.xt_matvec(x, w),)


def xmatvec(x, c):
    """y = X @ c  (the DiSCO-F down-sweep against the ReduceAll'd margins;
    the caller supplies c = s * t * inv_div and adds lam*u)."""
    return (matvec.x_scaled_matvec(x, c),)


def local_hvp(x, s, u, inv_div, lam):
    """Hu = inv_div * X diag(s) X^T u + lam*u  (Alg. 2/3 step 4)."""
    t = matvec.xt_matvec(x, u)
    y = matvec.x_scaled_matvec(x, s * t)
    return (inv_div * y + lam * u,)


def local_grad(x, z, y, inv_n, lam, w):
    """Shard gradient slice: inv_n * X phi'(z;y) + lam*w, from margins z."""

    def fn(loss):
        dv = _deriv(loss, z, y)
        g = matvec.x_scaled_matvec(x, dv)
        return (inv_n * g + lam * w,)

    return fn


def hessian_scalings(z, y, loss):
    """s_i = phi''(z_i; y_i) -- elementwise, no kernel needed."""
    return (_second(loss, z, y),)


def objective_terms(z, y, inv_n, loss):
    """Per-shard data objective: inv_n * sum phi(z_i; y_i) (scalar)."""
    return (inv_n * jnp.sum(_value(loss, z, y), keepdims=True),)


def woodbury_gram(u_scaled):
    """K = U~^T U~ (Alg. 4 inner matrix, before +I / 1/dreg in Rust)."""
    return (gram_k.gram(u_scaled),)


# ---------------------------------------------------------------------------
# Loss-specialized entry points (lowered by aot.py; names = artifact names)
# ---------------------------------------------------------------------------


def make_grad_fn(loss):
    def grad_fn(x, z, y, inv_n, lam, w):
        return local_grad(x, z, y, inv_n, lam, w)(loss)

    grad_fn.__name__ = f"grad_{loss}"
    return grad_fn


def make_scalings_fn(loss):
    def scalings_fn(z, y):
        return hessian_scalings(z, y, loss)

    scalings_fn.__name__ = f"scalings_{loss}"
    return scalings_fn


def make_objective_fn(loss):
    def objective_fn(z, y, inv_n):
        return objective_terms(z, y, inv_n, loss)

    objective_fn.__name__ = f"objective_{loss}"
    return objective_fn
