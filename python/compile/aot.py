"""AOT pipeline: lower the Layer-2 jax functions to HLO *text* artifacts.

Runs once at `make artifacts`; the Rust runtime
(rust/src/runtime/) loads artifacts/<name>.hlo.txt via
HloModuleProto::from_text_file, compiles on the PJRT CPU client, and
executes them on the request path. Python is never imported at runtime.

HLO text -- not `.serialize()` -- is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids. See
/opt/xla-example/README.md.

A manifest.json records every artifact's inputs/outputs (shape, dtype) so
the Rust registry can type-check calls at load time.

Shape variants cover the runtime demo configurations (see
rust/src/runtime/registry.rs): the `xla-demo` dataset d=1024, n=4096 on
m=4 nodes under both partitionings, plus the single-node quickstart.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# (d_shard, n_shard) variants: DiSCO-F shard (256, 4096), DiSCO-S shard
# (1024, 1024), single node (1024, 4096), and a tiny test shape (64, 128).
SHAPES = [(16, 128), (64, 128), (256, 4096), (1024, 1024), (1024, 4096)]
TAU = 128


def artifact_list():
    """Yield (name, function, example_args)."""
    for d, n in SHAPES:
        yield f"margins_{d}x{n}", model.margins, (spec(d, n), spec(d))
        yield f"xmatvec_{d}x{n}", model.xmatvec, (spec(d, n), spec(n))
        yield (
            f"hvp_{d}x{n}",
            model.local_hvp,
            (spec(d, n), spec(n), spec(d), spec(1), spec(1)),
        )
        for loss in model.LOSSES:
            yield (
                f"grad_{loss}_{d}x{n}",
                model.make_grad_fn(loss),
                (spec(d, n), spec(n), spec(n), spec(1), spec(1), spec(d)),
            )
    # Gram variants keyed by feature dimension only.
    for d in sorted({d for d, _ in SHAPES}):
        yield f"gram_{d}x{TAU}", model.woodbury_gram, (spec(d, TAU),)
    # Margin-only functions (shared across shard shapes by n).
    for n in sorted({n for _, n in SHAPES}):
        for loss in model.LOSSES:
            yield (
                f"scalings_{loss}_{n}",
                model.make_scalings_fn(loss),
                (spec(n), spec(n)),
            )
            yield (
                f"objective_{loss}_{n}",
                model.make_objective_fn(loss),
                (spec(n), spec(n), spec(1)),
            )


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, fn, args in artifact_list():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": "f32"}
            for s in jax.eval_shape(fn, *args)
        ]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(a.shape), "dtype": "f32"} for a in args],
            "outputs": out_shapes,
        }
        print(f"lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    args = p.parse_args()
    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):
        # Makefile passes the sentinel artifact path; use its directory.
        out_dir = os.path.dirname(out_dir)
    manifest = lower_all(out_dir)
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
